//! Simulated network cost model (DESIGN.md substitutions).
//!
//! The paper's timing structure comes from a physical cluster: GPU<->GPU
//! links (GPUDirect-class) are ~10x faster than CPU<->GPU links (§4.2.3).
//! Our logical nodes are threads, so real wire time is ~0; this model
//! *accounts* the time each transfer would have taken and the trainer adds it
//! to a simulated clock per phase. That preserves exactly what the Gantt /
//! throughput experiments measure: which phases overlap and who pays for
//! which bytes.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::NetModelConfig;

/// Link classes in the Persia topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Link {
    /// NN worker <-> NN worker (AllReduce fabric).
    GpuGpu,
    /// NN worker <-> embedding worker / PS (PCIe + Ethernet class).
    CpuGpu,
    /// embedding worker <-> embedding PS (CPU fabric; same class as CpuGpu).
    CpuCpu,
}

/// Thread-safe accumulator of simulated transfer time.
pub struct NetSim {
    cfg: NetModelConfig,
    /// Total simulated nanoseconds per link class.
    gpu_gpu_ns: AtomicU64,
    cpu_gpu_ns: AtomicU64,
    cpu_cpu_ns: AtomicU64,
    bytes_total: AtomicU64,
}

impl NetSim {
    pub fn new(cfg: NetModelConfig) -> Self {
        Self {
            cfg,
            gpu_gpu_ns: AtomicU64::new(0),
            cpu_gpu_ns: AtomicU64::new(0),
            cpu_cpu_ns: AtomicU64::new(0),
            bytes_total: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Simulated seconds one transfer of `bytes` takes on `link`.
    pub fn transfer_secs(&self, link: Link, bytes: usize) -> f64 {
        if !self.cfg.enabled() {
            return 0.0;
        }
        let bw = match link {
            Link::GpuGpu => self.cfg.gpu_gpu_bw,
            Link::CpuGpu | Link::CpuCpu => self.cfg.cpu_gpu_bw,
        };
        let serial = if bw > 0.0 { bytes as f64 / bw } else { 0.0 };
        self.cfg.latency_s + serial
    }

    /// Account a transfer; returns its simulated duration in seconds.
    pub fn record(&self, link: Link, bytes: usize) -> f64 {
        let secs = self.transfer_secs(link, bytes);
        let ns = (secs * 1e9) as u64;
        match link {
            Link::GpuGpu => self.gpu_gpu_ns.fetch_add(ns, Ordering::Relaxed),
            Link::CpuGpu => self.cpu_gpu_ns.fetch_add(ns, Ordering::Relaxed),
            Link::CpuCpu => self.cpu_cpu_ns.fetch_add(ns, Ordering::Relaxed),
        };
        self.bytes_total.fetch_add(bytes as u64, Ordering::Relaxed);
        secs
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_total.load(Ordering::Relaxed)
    }

    /// Accumulated simulated seconds per class: (gpu_gpu, cpu_gpu, cpu_cpu).
    pub fn totals_secs(&self) -> (f64, f64, f64) {
        (
            self.gpu_gpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.cpu_gpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.cpu_cpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_model_is_free() {
        let sim = NetSim::new(NetModelConfig::disabled());
        assert_eq!(sim.transfer_secs(Link::GpuGpu, 1 << 30), 0.0);
        assert_eq!(sim.record(Link::CpuGpu, 1 << 20), 0.0);
    }

    #[test]
    fn gpu_link_is_10x_faster() {
        let sim = NetSim::new(NetModelConfig::paper_like());
        let bytes = 100 << 20;
        let fast = sim.transfer_secs(Link::GpuGpu, bytes);
        let slow = sim.transfer_secs(Link::CpuGpu, bytes);
        let ratio = (slow - 50e-6) / (fast - 50e-6);
        assert!((ratio - 10.0).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn accounting_accumulates() {
        let sim = NetSim::new(NetModelConfig::paper_like());
        sim.record(Link::GpuGpu, 1 << 20);
        sim.record(Link::GpuGpu, 1 << 20);
        sim.record(Link::CpuCpu, 1 << 10);
        let (g, c, cc) = sim.totals_secs();
        assert!(g > 0.0 && cc > 0.0);
        assert_eq!(c, 0.0);
        assert_eq!(sim.total_bytes(), (2 << 20) + (1 << 10));
    }

    #[test]
    fn latency_dominates_small_messages() {
        let sim = NetSim::new(NetModelConfig::paper_like());
        let t = sim.transfer_secs(Link::CpuGpu, 64);
        assert!((t - 50e-6).abs() / 50e-6 < 0.01, "t={t}");
    }
}
