//! Simulated network cost model (DESIGN.md substitutions).
//!
//! The paper's timing structure comes from a physical cluster: GPU<->GPU
//! links (GPUDirect-class) are ~10x faster than CPU<->GPU links (§4.2.3).
//! Our logical nodes are threads, so real wire time is ~0; this model
//! *accounts* the time each transfer would have taken and the trainer adds it
//! to a simulated clock per phase. That preserves exactly what the Gantt /
//! throughput experiments measure: which phases overlap and who pays for
//! which bytes.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::NetModelConfig;

/// Link classes in the Persia topology.
///
/// The three-tier deployment names its links after the roles they join; the
/// [`Link::PS_EW`] / [`Link::EW_NN`] associated constants map those names
/// onto the two hardware classes so every tier charges the same accountant:
///
/// ```text
///   embedding PS ──PS_EW (CpuCpu)──▶ embedding worker ──EW_NN (CpuGpu)──▶ NN worker
///                                                         NN worker ◀─GpuGpu─▶ NN worker
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Link {
    /// NN worker <-> NN worker (AllReduce fabric).
    GpuGpu,
    /// NN worker <-> embedding worker / PS (PCIe + Ethernet class).
    CpuGpu,
    /// embedding worker <-> embedding PS (CPU fabric; same class as CpuGpu).
    CpuCpu,
}

impl Link {
    /// The embedding-PS ↔ embedding-worker link (row fetches and gradient
    /// puts; CPU-fabric class). Charged by [`crate::worker::EmbeddingWorker`]
    /// for the deduplicated rows it actually moves — in-process and in the
    /// `serve-embedding-worker` tier alike.
    pub const PS_EW: Link = Link::CpuCpu;
    /// The embedding-worker ↔ NN-worker link (pooled activations forward,
    /// activation gradients backward; PCIe/Ethernet class). In-process the
    /// transfer is simulated; across the `serve-embedding-worker` wire it is
    /// charged with the frame bytes actually sent.
    pub const EW_NN: Link = Link::CpuGpu;
}

/// Thread-safe accumulator of simulated transfer time.
pub struct NetSim {
    cfg: NetModelConfig,
    /// Total simulated nanoseconds per link class.
    gpu_gpu_ns: AtomicU64,
    cpu_gpu_ns: AtomicU64,
    cpu_cpu_ns: AtomicU64,
    /// Bytes actually recorded per link class — for GpuGpu this is what the
    /// dense AllReduce transport really put on the wire (frame bytes, halved
    /// payloads under fp16 compression), not a nominal payload size.
    gpu_gpu_bytes: AtomicU64,
    cpu_gpu_bytes: AtomicU64,
    cpu_cpu_bytes: AtomicU64,
    bytes_total: AtomicU64,
}

impl NetSim {
    pub fn new(cfg: NetModelConfig) -> Self {
        Self {
            cfg,
            gpu_gpu_ns: AtomicU64::new(0),
            cpu_gpu_ns: AtomicU64::new(0),
            cpu_cpu_ns: AtomicU64::new(0),
            gpu_gpu_bytes: AtomicU64::new(0),
            cpu_gpu_bytes: AtomicU64::new(0),
            cpu_cpu_bytes: AtomicU64::new(0),
            bytes_total: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Simulated seconds one transfer of `bytes` takes on `link`.
    pub fn transfer_secs(&self, link: Link, bytes: usize) -> f64 {
        if !self.cfg.enabled() {
            return 0.0;
        }
        let bw = match link {
            Link::GpuGpu => self.cfg.gpu_gpu_bw,
            Link::CpuGpu | Link::CpuCpu => self.cfg.cpu_gpu_bw,
        };
        let serial = if bw > 0.0 { bytes as f64 / bw } else { 0.0 };
        self.cfg.latency_s + serial
    }

    /// Account a transfer; returns its simulated duration in seconds.
    pub fn record(&self, link: Link, bytes: usize) -> f64 {
        let secs = self.transfer_secs(link, bytes);
        let ns = (secs * 1e9) as u64;
        match link {
            Link::GpuGpu => {
                self.gpu_gpu_ns.fetch_add(ns, Ordering::Relaxed);
                self.gpu_gpu_bytes.fetch_add(bytes as u64, Ordering::Relaxed)
            }
            Link::CpuGpu => {
                self.cpu_gpu_ns.fetch_add(ns, Ordering::Relaxed);
                self.cpu_gpu_bytes.fetch_add(bytes as u64, Ordering::Relaxed)
            }
            Link::CpuCpu => {
                self.cpu_cpu_ns.fetch_add(ns, Ordering::Relaxed);
                self.cpu_cpu_bytes.fetch_add(bytes as u64, Ordering::Relaxed)
            }
        };
        self.bytes_total.fetch_add(bytes as u64, Ordering::Relaxed);
        secs
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_total.load(Ordering::Relaxed)
    }

    /// Bytes recorded against one link class.
    pub fn link_bytes(&self, link: Link) -> u64 {
        match link {
            Link::GpuGpu => self.gpu_gpu_bytes.load(Ordering::Relaxed),
            Link::CpuGpu => self.cpu_gpu_bytes.load(Ordering::Relaxed),
            Link::CpuCpu => self.cpu_cpu_bytes.load(Ordering::Relaxed),
        }
    }

    /// Simulated nanoseconds recorded against one link class.
    pub fn link_ns(&self, link: Link) -> u64 {
        match link {
            Link::GpuGpu => self.gpu_gpu_ns.load(Ordering::Relaxed),
            Link::CpuGpu => self.cpu_gpu_ns.load(Ordering::Relaxed),
            Link::CpuCpu => self.cpu_cpu_ns.load(Ordering::Relaxed),
        }
    }

    /// Accumulated simulated seconds per class: (gpu_gpu, cpu_gpu, cpu_cpu).
    pub fn totals_secs(&self) -> (f64, f64, f64) {
        (
            self.gpu_gpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.cpu_gpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.cpu_cpu_ns.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_model_is_free() {
        let sim = NetSim::new(NetModelConfig::disabled());
        assert_eq!(sim.transfer_secs(Link::GpuGpu, 1 << 30), 0.0);
        assert_eq!(sim.record(Link::CpuGpu, 1 << 20), 0.0);
    }

    #[test]
    fn gpu_link_is_10x_faster() {
        let sim = NetSim::new(NetModelConfig::paper_like());
        let bytes = 100 << 20;
        let fast = sim.transfer_secs(Link::GpuGpu, bytes);
        let slow = sim.transfer_secs(Link::CpuGpu, bytes);
        let ratio = (slow - 50e-6) / (fast - 50e-6);
        assert!((ratio - 10.0).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn accounting_accumulates() {
        let sim = NetSim::new(NetModelConfig::paper_like());
        sim.record(Link::GpuGpu, 1 << 20);
        sim.record(Link::GpuGpu, 1 << 20);
        sim.record(Link::CpuCpu, 1 << 10);
        let (g, c, cc) = sim.totals_secs();
        assert!(g > 0.0 && cc > 0.0);
        assert_eq!(c, 0.0);
        assert_eq!(sim.total_bytes(), (2 << 20) + (1 << 10));
    }

    #[test]
    fn per_link_bytes_and_ns_are_isolated() {
        // The dense-transport swap must only ever show up on the GpuGpu
        // link: recording AllReduce traffic leaves CpuGpu/CpuCpu untouched.
        let sim = NetSim::new(NetModelConfig::paper_like());
        sim.record(Link::GpuGpu, 1 << 20);
        sim.record(Link::GpuGpu, 1 << 20);
        assert_eq!(sim.link_bytes(Link::GpuGpu), 2 << 20);
        assert_eq!(sim.link_bytes(Link::CpuGpu), 0);
        assert_eq!(sim.link_bytes(Link::CpuCpu), 0);
        assert!(sim.link_ns(Link::GpuGpu) > 0);
        assert_eq!(sim.link_ns(Link::CpuGpu), 0);
        assert_eq!(sim.link_ns(Link::CpuCpu), 0);
    }

    #[test]
    fn gpu_gpu_ns_scale_linearly_with_bytes() {
        // Beyond the fixed per-message latency, simulated GpuGpu time is
        // strictly proportional to bytes: doubling the payload doubles the
        // serialization term.
        let sim = NetSim::new(NetModelConfig::paper_like());
        let lat = NetModelConfig::paper_like().latency_s;
        let b = 1 << 22;
        let t1 = sim.transfer_secs(Link::GpuGpu, b) - lat;
        let t2 = sim.transfer_secs(Link::GpuGpu, 2 * b) - lat;
        let t8 = sim.transfer_secs(Link::GpuGpu, 8 * b) - lat;
        assert!((t2 / t1 - 2.0).abs() < 1e-9, "t2/t1={}", t2 / t1);
        assert!((t8 / t1 - 8.0).abs() < 1e-9, "t8/t1={}", t8 / t1);
    }

    #[test]
    fn recorded_ns_match_transfer_secs() {
        let sim = NetSim::new(NetModelConfig::paper_like());
        let b = 123_456;
        let want = sim.transfer_secs(Link::GpuGpu, b);
        let got = sim.record(Link::GpuGpu, b);
        assert_eq!(want, got);
        // Accumulator truncates to whole nanoseconds.
        assert!((sim.link_ns(Link::GpuGpu) as f64 / 1e9 - want).abs() < 2e-9);
    }

    #[test]
    fn tier_link_aliases_share_their_hardware_class_accounting() {
        // PS↔EW and EW↔NN are names for the Cpu links: bytes recorded under
        // the alias land on the aliased class (one accountant per class).
        let sim = NetSim::new(NetModelConfig::paper_like());
        sim.record(Link::PS_EW, 100);
        sim.record(Link::EW_NN, 200);
        assert_eq!(sim.link_bytes(Link::CpuCpu), 100);
        assert_eq!(sim.link_bytes(Link::CpuGpu), 200);
        assert_eq!(sim.link_bytes(Link::GpuGpu), 0);
        assert_eq!(Link::PS_EW, Link::CpuCpu);
        assert_eq!(Link::EW_NN, Link::CpuGpu);
    }

    #[test]
    fn latency_dominates_small_messages() {
        let sim = NetSim::new(NetModelConfig::paper_like());
        let t = sim.transfer_secs(Link::CpuGpu, 64);
        assert!((t - 50e-6).abs() / 50e-6 < 0.01, "t={t}");
    }
}
