//! Zero-copy tensor wire format (paper §4.2.3, "Optimized remote procedure
//! call").
//!
//! Persia abandons protobuf-style serialization because the payloads are
//! tensors in large contiguous buffers: the wire format here is a flat header
//! (tag + section lengths) followed by the raw little-endian bytes of each
//! section, so encoding f32/u64/u16 slices is a single `memcpy` each —
//! no per-element branching, no intermediate objects. Decoding returns
//! borrowed slices wherever alignment permits.

/// Section type tags (purely diagnostic; layout is positional).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SectionTag {
    F32 = 1,
    U64 = 2,
    U16 = 3,
    U8 = 4,
    F16 = 5,
}

impl SectionTag {
    fn from_u8(x: u8) -> Option<Self> {
        Some(match x {
            1 => SectionTag::F32,
            2 => SectionTag::U64,
            3 => SectionTag::U16,
            4 => SectionTag::U8,
            5 => SectionTag::F16,
            _ => return None,
        })
    }

    fn elem_size(self) -> usize {
        match self {
            SectionTag::F32 => 4,
            SectionTag::U64 => 8,
            SectionTag::U16 | SectionTag::F16 => 2,
            SectionTag::U8 => 1,
        }
    }
}

/// Message writer: appends typed sections into one contiguous buffer.
///
/// Layout: `[magic u32][msg_kind u32][n_sections u32]` then per section
/// `[tag u8][pad 3][len_elems u64]`, then all payloads back to back, each
/// 8-byte aligned.
///
/// ```
/// use persia::comm::wire::{WireReader, WireWriter};
/// let mut w = WireWriter::new(7);
/// w.put_u64(&[1, 2, 3]).put_f32(&[0.5, -2.0]);
/// let msg = w.finish();
/// let r = WireReader::parse(&msg).unwrap();
/// assert_eq!(r.kind(), 7);
/// assert_eq!(r.u64(0).unwrap(), vec![1, 2, 3]);
/// assert_eq!(r.f32(1).unwrap(), vec![0.5, -2.0]);
/// ```
pub struct WireWriter {
    buf: Vec<u8>,
    sections: Vec<(SectionTag, usize, usize)>, // tag, offset, elems
    kind: u32,
}

const MAGIC: u32 = 0x5045_5253; // "PERS"

impl WireWriter {
    pub fn new(kind: u32) -> Self {
        Self { buf: Vec::new(), sections: Vec::new(), kind }
    }

    /// Reuse an allocation from a previous message (hot-path, alloc-free).
    pub fn reset(&mut self, kind: u32) {
        self.buf.clear();
        self.sections.clear();
        self.kind = kind;
    }

    fn align8(&mut self) {
        while self.buf.len() % 8 != 0 {
            self.buf.push(0);
        }
    }

    fn push_raw(&mut self, tag: SectionTag, bytes: &[u8], elems: usize) {
        self.align8();
        let off = self.buf.len();
        self.buf.extend_from_slice(bytes);
        self.sections.push((tag, off, elems));
    }

    pub fn put_f32(&mut self, xs: &[f32]) -> &mut Self {
        // SAFETY: f32 -> bytes reinterpret; little-endian on all targets here.
        let bytes = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
        };
        self.push_raw(SectionTag::F32, bytes, xs.len());
        self
    }

    pub fn put_u64(&mut self, xs: &[u64]) -> &mut Self {
        let bytes = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8)
        };
        self.push_raw(SectionTag::U64, bytes, xs.len());
        self
    }

    pub fn put_u16(&mut self, xs: &[u16]) -> &mut Self {
        let bytes = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 2)
        };
        self.push_raw(SectionTag::U16, bytes, xs.len());
        self
    }

    pub fn put_f16(&mut self, xs: &[u16]) -> &mut Self {
        let bytes = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 2)
        };
        self.push_raw(SectionTag::F16, bytes, xs.len());
        self
    }

    pub fn put_u8(&mut self, xs: &[u8]) -> &mut Self {
        self.push_raw(SectionTag::U8, xs, xs.len());
        self
    }

    /// Assemble the final message bytes.
    pub fn finish(&self) -> Vec<u8> {
        let header_len = 12 + self.sections.len() * 12;
        let payload_base = (header_len + 7) / 8 * 8;
        let mut out = Vec::with_capacity(payload_base + self.buf.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&self.kind.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for &(tag, off, elems) in &self.sections {
            out.push(tag as u8);
            out.extend_from_slice(&[0u8; 3]);
            out.extend_from_slice(&((payload_base + off) as u32).to_le_bytes());
            out.extend_from_slice(&(elems as u32).to_le_bytes());
        }
        while out.len() < payload_base {
            out.push(0);
        }
        out.extend_from_slice(&self.buf);
        out
    }
}

/// Message reader over a received byte buffer.
pub struct WireReader<'a> {
    data: &'a [u8],
    sections: Vec<(SectionTag, usize, usize)>, // tag, byte offset, elems
    kind: u32,
}

impl<'a> WireReader<'a> {
    pub fn parse(data: &'a [u8]) -> anyhow::Result<Self> {
        use anyhow::bail;
        if data.len() < 12 {
            bail!("short message ({} bytes)", data.len());
        }
        let magic = u32::from_le_bytes(data[0..4].try_into().unwrap());
        if magic != MAGIC {
            bail!("bad magic {magic:#x}");
        }
        let kind = u32::from_le_bytes(data[4..8].try_into().unwrap());
        let n = u32::from_le_bytes(data[8..12].try_into().unwrap()) as usize;
        let mut sections = Vec::with_capacity(n);
        let mut p = 12;
        for _ in 0..n {
            if p + 12 > data.len() {
                bail!("truncated section table");
            }
            let tag = SectionTag::from_u8(data[p]).ok_or_else(|| anyhow::anyhow!("bad tag"))?;
            let off = u32::from_le_bytes(data[p + 4..p + 8].try_into().unwrap()) as usize;
            let elems = u32::from_le_bytes(data[p + 8..p + 12].try_into().unwrap()) as usize;
            if off + elems * tag.elem_size() > data.len() {
                bail!("section out of bounds");
            }
            sections.push((tag, off, elems));
            p += 12;
        }
        Ok(Self { data, sections, kind })
    }

    pub fn kind(&self) -> u32 {
        self.kind
    }

    pub fn n_sections(&self) -> usize {
        self.sections.len()
    }

    fn section(&self, i: usize, want: SectionTag) -> anyhow::Result<(usize, usize)> {
        let &(tag, off, elems) = self
            .sections
            .get(i)
            .ok_or_else(|| anyhow::anyhow!("no section {i}"))?;
        if tag != want {
            anyhow::bail!("section {i}: expected {want:?}, got {tag:?}");
        }
        Ok((off, elems))
    }

    /// Borrow section `i` as f32s (zero-copy when aligned, else copies).
    pub fn f32(&self, i: usize) -> anyhow::Result<Vec<f32>> {
        let (off, elems) = self.section(i, SectionTag::F32)?;
        let bytes = &self.data[off..off + elems * 4];
        let mut out = vec![0f32; elems];
        // SAFETY: lengths match; copy handles any alignment.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        Ok(out)
    }

    /// Zero-copy borrow of section `i` as f32 slice; requires 4-alignment
    /// (guaranteed by WireWriter's 8-byte section alignment).
    pub fn f32_borrowed(&self, i: usize) -> anyhow::Result<&'a [f32]> {
        let (off, elems) = self.section(i, SectionTag::F32)?;
        let ptr = self.data[off..].as_ptr();
        anyhow::ensure!(ptr as usize % 4 == 0, "unaligned f32 section");
        Ok(unsafe { std::slice::from_raw_parts(ptr as *const f32, elems) })
    }

    pub fn u64(&self, i: usize) -> anyhow::Result<Vec<u64>> {
        let (off, elems) = self.section(i, SectionTag::U64)?;
        let mut out = vec![0u64; elems];
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data[off..].as_ptr(),
                out.as_mut_ptr() as *mut u8,
                elems * 8,
            );
        }
        Ok(out)
    }

    pub fn u16(&self, i: usize) -> anyhow::Result<Vec<u16>> {
        let (off, elems) = self.section(i, SectionTag::U16)?;
        let mut out = vec![0u16; elems];
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data[off..].as_ptr(),
                out.as_mut_ptr() as *mut u8,
                elems * 2,
            );
        }
        Ok(out)
    }

    pub fn f16(&self, i: usize) -> anyhow::Result<Vec<u16>> {
        let (off, elems) = self.section(i, SectionTag::F16)?;
        let mut out = vec![0u16; elems];
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data[off..].as_ptr(),
                out.as_mut_ptr() as *mut u8,
                elems * 2,
            );
        }
        Ok(out)
    }

    pub fn u8(&self, i: usize) -> anyhow::Result<&'a [u8]> {
        let (off, elems) = self.section(i, SectionTag::U8)?;
        Ok(&self.data[off..off + elems])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{forall, gen_f32_vec};

    #[test]
    fn roundtrip_mixed_sections() {
        let mut w = WireWriter::new(7);
        w.put_f32(&[1.5, -2.5, 3.25])
            .put_u64(&[42, u64::MAX])
            .put_u16(&[1, 2, 3])
            .put_u8(b"hello");
        let msg = w.finish();
        let r = WireReader::parse(&msg).unwrap();
        assert_eq!(r.kind(), 7);
        assert_eq!(r.n_sections(), 4);
        assert_eq!(r.f32(0).unwrap(), vec![1.5, -2.5, 3.25]);
        assert_eq!(r.u64(1).unwrap(), vec![42, u64::MAX]);
        assert_eq!(r.u16(2).unwrap(), vec![1, 2, 3]);
        assert_eq!(r.u8(3).unwrap(), b"hello");
    }

    #[test]
    fn zero_copy_borrow_works() {
        let mut w = WireWriter::new(1);
        w.put_f32(&[9.0, 8.0, 7.0]);
        let msg = w.finish();
        let r = WireReader::parse(&msg).unwrap();
        assert_eq!(r.f32_borrowed(0).unwrap(), &[9.0, 8.0, 7.0]);
    }

    #[test]
    fn wrong_tag_rejected() {
        let mut w = WireWriter::new(1);
        w.put_f32(&[1.0]);
        let msg = w.finish();
        let r = WireReader::parse(&msg).unwrap();
        assert!(r.u64(0).is_err());
        assert!(r.f32(1).is_err());
    }

    #[test]
    fn corrupt_messages_rejected_not_panicking() {
        assert!(WireReader::parse(&[]).is_err());
        assert!(WireReader::parse(&[0u8; 11]).is_err());
        let mut w = WireWriter::new(1);
        w.put_f32(&[1.0, 2.0]);
        let mut msg = w.finish();
        msg[0] ^= 0xff; // break magic
        assert!(WireReader::parse(&msg).is_err());
        let mut w = WireWriter::new(1);
        w.put_f32(&[1.0, 2.0]);
        let mut msg2 = w.finish();
        let len = msg2.len();
        msg2.truncate(len - 4); // truncate payload
        assert!(WireReader::parse(&msg2).is_err());
    }

    #[test]
    fn property_f32_roundtrip_bit_exact() {
        forall(21, 200, gen_f32_vec(256, 1e6), |xs| {
            let mut w = WireWriter::new(0);
            w.put_f32(xs);
            let msg = w.finish();
            let r = WireReader::parse(&msg).unwrap();
            r.f32(0).unwrap() == *xs
        });
    }

    #[test]
    fn writer_reset_reuses_allocation() {
        let mut w = WireWriter::new(1);
        w.put_f32(&vec![1.0; 1024]);
        let _ = w.finish();
        w.reset(2);
        w.put_u64(&[5]);
        let msg = w.finish();
        let r = WireReader::parse(&msg).unwrap();
        assert_eq!(r.kind(), 2);
        assert_eq!(r.u64(0).unwrap(), vec![5]);
    }
}
