//! Communication substrate: zero-copy wire format, compression, transports,
//! RPC, and the simulated network cost model.

pub mod compress;
pub mod netsim;
pub mod rpc;
pub mod transport;
pub mod wire;

pub use compress::{CompressedValues, IndexMap};
pub use netsim::NetSim;
pub use rpc::{RpcClient, RpcServer};
pub use transport::{ChannelTransport, Transport};
pub use wire::{WireReader, WireWriter};
