//! Communication substrate: zero-copy wire format, compression, transports,
//! RPC, and the simulated network cost model.

pub mod compress;
pub mod netsim;
#[cfg(unix)]
pub mod poll;
pub mod rpc;
pub mod transport;
pub mod wire;

pub use compress::{CompressedValues, IndexMap};
pub use netsim::NetSim;
pub use rpc::{PendingReply, PipelinedClient, RpcClient, RpcServer};
pub use transport::{ChannelTransport, Transport};
pub use wire::{WireReader, WireWriter};
