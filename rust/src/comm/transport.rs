//! Message transports: in-process channels (default) and TCP framing.
//!
//! Both carry opaque byte frames produced by [`super::wire`]. The in-process
//! transport is the default for the simulated cluster (one OS thread per
//! logical node); the TCP transport backs the true multi-process mode
//! (`persia ps-server` / `persia worker`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use crate::util::lock_unpoisoned;

/// A bidirectional frame pipe.
pub trait Transport: Send {
    fn send(&self, frame: Vec<u8>) -> anyhow::Result<()>;
    fn recv(&self) -> anyhow::Result<Vec<u8>>;
    fn try_recv(&self) -> anyhow::Result<Option<Vec<u8>>>;
}

/// In-process transport endpoint (mpsc-backed).
pub struct ChannelTransport {
    tx: Sender<Vec<u8>>,
    rx: Mutex<Receiver<Vec<u8>>>,
}

impl ChannelTransport {
    /// Create a connected pair of endpoints.
    pub fn pair() -> (ChannelTransport, ChannelTransport) {
        let (tx_a, rx_b) = channel();
        let (tx_b, rx_a) = channel();
        (
            ChannelTransport { tx: tx_a, rx: Mutex::new(rx_a) },
            ChannelTransport { tx: tx_b, rx: Mutex::new(rx_b) },
        )
    }
}

impl Transport for ChannelTransport {
    fn send(&self, frame: Vec<u8>) -> anyhow::Result<()> {
        self.tx.send(frame).map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    fn recv(&self) -> anyhow::Result<Vec<u8>> {
        self.rx
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    fn try_recv(&self) -> anyhow::Result<Option<Vec<u8>>> {
        use std::sync::mpsc::TryRecvError;
        match self.rx.lock().unwrap().try_recv() {
            Ok(f) => Ok(Some(f)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => anyhow::bail!("peer disconnected"),
        }
    }
}

/// Length-prefixed frames over a TCP stream (u32 LE length + payload).
pub struct TcpTransport {
    stream: Mutex<TcpStream>,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Self {
        stream.set_nodelay(true).ok();
        Self { stream: Mutex::new(stream) }
    }

    pub fn connect(addr: &str) -> anyhow::Result<Self> {
        Ok(Self::new(TcpStream::connect(addr)?))
    }

    /// Bound every subsequent read/write on the underlying socket (`None`
    /// restores blocking forever). The NN-worker ring uses this so a dead
    /// peer surfaces as an error within the ring timeout instead of a hang.
    pub fn set_timeouts(&self, dur: Option<std::time::Duration>) -> anyhow::Result<()> {
        let s = lock_unpoisoned(&self.stream);
        s.set_read_timeout(dur)?;
        s.set_write_timeout(dur)?;
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn send(&self, frame: Vec<u8>) -> anyhow::Result<()> {
        let mut s = lock_unpoisoned(&self.stream);
        s.write_all(&(frame.len() as u32).to_le_bytes())?;
        s.write_all(&frame)?;
        Ok(())
    }

    fn recv(&self) -> anyhow::Result<Vec<u8>> {
        let mut s = lock_unpoisoned(&self.stream);
        let mut len_buf = [0u8; 4];
        s.read_exact(&mut len_buf)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        anyhow::ensure!(len < 1 << 30, "oversized frame {len}");
        let mut buf = vec![0u8; len];
        s.read_exact(&mut buf)?;
        Ok(buf)
    }

    fn try_recv(&self) -> anyhow::Result<Option<Vec<u8>>> {
        // Blocking recv is fine for the TCP service loops.
        self.recv().map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_pair_roundtrip() {
        let (a, b) = ChannelTransport::pair();
        a.send(vec![1, 2, 3]).unwrap();
        assert_eq!(b.recv().unwrap(), vec![1, 2, 3]);
        b.send(vec![9]).unwrap();
        assert_eq!(a.recv().unwrap(), vec![9]);
    }

    #[test]
    fn channel_try_recv_nonblocking() {
        let (a, b) = ChannelTransport::pair();
        assert!(b.try_recv().unwrap().is_none());
        a.send(vec![7]).unwrap();
        assert_eq!(b.try_recv().unwrap(), Some(vec![7]));
    }

    #[test]
    fn channel_disconnect_is_error() {
        let (a, b) = ChannelTransport::pair();
        drop(b);
        assert!(a.send(vec![0]).is_err());
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t = TcpTransport::new(stream);
            let frame = t.recv().unwrap();
            t.send(frame.iter().rev().cloned().collect()).unwrap();
        });
        let client = TcpTransport::connect(&addr.to_string()).unwrap();
        client.send(vec![1, 2, 3]).unwrap();
        assert_eq!(client.recv().unwrap(), vec![3, 2, 1]);
        server.join().unwrap();
    }
}
