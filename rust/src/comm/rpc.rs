//! Request/response RPC over any [`Transport`].
//!
//! The paper's point-to-point traffic (NN worker <-> embedding worker,
//! embedding worker <-> embedding PS) is RPC over the zero-copy wire format
//! — not protobuf (§4.2.3). A server registers one handler per message kind;
//! requests carry a correlation id so a client can pipeline.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use super::transport::Transport;

/// Frame layout: `[corr_id u64][wire message bytes]`.
fn frame(corr_id: u64, msg: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + msg.len());
    out.extend_from_slice(&corr_id.to_le_bytes());
    out.extend_from_slice(msg);
    out
}

fn unframe(frame: &[u8]) -> anyhow::Result<(u64, &[u8])> {
    anyhow::ensure!(frame.len() >= 8, "short rpc frame");
    let corr = u64::from_le_bytes(frame[..8].try_into().unwrap());
    Ok((corr, &frame[8..]))
}

/// Handler: raw wire-message bytes in, raw wire-message bytes out.
pub type Handler = Box<dyn Fn(&[u8]) -> anyhow::Result<Vec<u8>> + Send + Sync>;

/// RPC server: dispatches by the wire message's `kind` field.
pub struct RpcServer {
    handlers: HashMap<u32, Handler>,
    stop: Arc<AtomicBool>,
}

impl Default for RpcServer {
    fn default() -> Self {
        Self::new()
    }
}

impl RpcServer {
    pub fn new() -> Self {
        Self { handlers: HashMap::new(), stop: Arc::new(AtomicBool::new(false)) }
    }

    pub fn register(&mut self, kind: u32, handler: Handler) -> &mut Self {
        self.handlers.insert(kind, handler);
        self
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Serve one connection until the peer disconnects or `stop` is set.
    pub fn serve<T: Transport>(&self, transport: &T) -> anyhow::Result<()> {
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            let req = match transport.recv() {
                Ok(f) => f,
                Err(_) => return Ok(()), // disconnect = normal shutdown
            };
            let (corr, msg) = unframe(&req)?;
            let kind = if msg.len() >= 8 {
                u32::from_le_bytes(msg[4..8].try_into().unwrap())
            } else {
                anyhow::bail!("short wire message");
            };
            let resp = match self.handlers.get(&kind) {
                Some(h) => h(msg)?,
                None => anyhow::bail!("no handler for kind {kind}"),
            };
            transport.send(frame(corr, &resp))?;
        }
    }
}

/// RPC client over a transport (single outstanding request per call;
/// the trainer pipelines by using one client per in-flight stream).
pub struct RpcClient<T: Transport> {
    transport: T,
    next_corr: AtomicU64,
}

impl<T: Transport> RpcClient<T> {
    pub fn new(transport: T) -> Self {
        Self { transport, next_corr: AtomicU64::new(1) }
    }

    /// Send a wire message; block for the matching response.
    pub fn call(&self, msg: &[u8]) -> anyhow::Result<Vec<u8>> {
        let corr = self.next_corr.fetch_add(1, Ordering::Relaxed);
        self.transport.send(frame(corr, msg))?;
        loop {
            let resp = self.transport.recv()?;
            let (rcorr, body) = unframe(&resp)?;
            if rcorr == corr {
                return Ok(body.to_vec());
            }
            // Out-of-order response for a different stream: ignore (callers
            // serialize per-client, so this only happens after errors).
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::ChannelTransport;
    use crate::comm::wire::{WireReader, WireWriter};

    #[test]
    fn echo_rpc_roundtrip() {
        let (server_t, client_t) = ChannelTransport::pair();
        let mut server = RpcServer::new();
        server.register(
            5,
            Box::new(|msg| {
                let r = WireReader::parse(msg)?;
                let xs = r.f32(0)?;
                let doubled: Vec<f32> = xs.iter().map(|x| x * 2.0).collect();
                let mut w = WireWriter::new(5);
                w.put_f32(&doubled);
                Ok(w.finish())
            }),
        );
        let handle = std::thread::spawn(move || server.serve(&server_t).unwrap());

        let client = RpcClient::new(client_t);
        let mut w = WireWriter::new(5);
        w.put_f32(&[1.0, 2.0]);
        let resp = client.call(&w.finish()).unwrap();
        let r = WireReader::parse(&resp).unwrap();
        assert_eq!(r.f32(0).unwrap(), vec![2.0, 4.0]);
        drop(client);
        handle.join().unwrap();
    }

    #[test]
    fn unknown_kind_errors_server_side() {
        let (server_t, client_t) = ChannelTransport::pair();
        let server = RpcServer::new();
        let handle = std::thread::spawn(move || server.serve(&server_t));
        let client = RpcClient::new(client_t);
        let w = WireWriter::new(99);
        // Server errors out and drops the connection; the call fails.
        assert!(client.call(&w.finish()).is_err());
        assert!(handle.join().unwrap().is_err());
    }

    #[test]
    fn sequential_calls_share_connection() {
        let (server_t, client_t) = ChannelTransport::pair();
        let mut server = RpcServer::new();
        server.register(1, Box::new(|msg| Ok(msg.to_vec())));
        let handle = std::thread::spawn(move || server.serve(&server_t).unwrap());
        let client = RpcClient::new(client_t);
        for i in 0..10u64 {
            let mut w = WireWriter::new(1);
            w.put_u64(&[i]);
            let resp = client.call(&w.finish()).unwrap();
            let r = WireReader::parse(&resp).unwrap();
            assert_eq!(r.u64(0).unwrap(), vec![i]);
        }
        drop(client);
        handle.join().unwrap();
    }
}
