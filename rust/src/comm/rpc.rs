//! Request/response RPC over any [`Transport`].
//!
//! The paper's point-to-point traffic (NN worker <-> embedding worker,
//! embedding worker <-> embedding PS) is RPC over the zero-copy wire format
//! — not protobuf (§4.2.3). A server registers one handler per message
//! kind; requests carry a correlation id so a client can pipeline.
//!
//! Two clients speak this protocol:
//!
//! * [`RpcClient`] — lock-step call/response over any [`Transport`]
//!   (used by handshake probes and the in-proc channel transport).
//! * [`PipelinedClient`] — TCP-only, `window` requests in flight on one
//!   connection: sends are sequence-tagged, a background reader demuxes
//!   responses into a completion map by correlation id, and callers block
//!   only on *their* reply ([`PendingReply::wait`]). Every wait is bounded
//!   by the client's I/O deadline, so a server that accepts and then wedges
//!   trips the recovery layer instead of hanging the trainer.
//!
//! On the server side [`RpcServer::dispatch_frame`] is the transport-free
//! core (unframe → handler → re-frame), shared by the blocking
//! [`RpcServer::serve`] loop and the readiness-loop server in
//! [`crate::service`].

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::util::lock_unpoisoned;

use super::transport::Transport;

/// Frame layout: `[corr_id u64][wire message bytes]`.
fn frame(corr_id: u64, msg: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + msg.len());
    out.extend_from_slice(&corr_id.to_le_bytes());
    out.extend_from_slice(msg);
    out
}

fn unframe(frame: &[u8]) -> Result<(u64, &[u8])> {
    ensure!(frame.len() >= 8, "short rpc frame");
    let corr = u64::from_le_bytes(frame[..8].try_into().unwrap());
    Ok((corr, &frame[8..]))
}

/// Handler: raw wire-message bytes in, raw wire-message bytes out.
pub type Handler = Box<dyn Fn(&[u8]) -> Result<Vec<u8>> + Send + Sync>;

/// RPC server: dispatches by the wire message's `kind` field.
pub struct RpcServer {
    handlers: HashMap<u32, Handler>,
    stop: Arc<AtomicBool>,
}

impl Default for RpcServer {
    fn default() -> Self {
        Self::new()
    }
}

impl RpcServer {
    pub fn new() -> Self {
        Self { handlers: HashMap::new(), stop: Arc::new(AtomicBool::new(false)) }
    }

    pub fn register(&mut self, kind: u32, handler: Handler) -> &mut Self {
        self.handlers.insert(kind, handler);
        self
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Dispatch one wire message to its kind's handler. This is the
    /// transport-free request core shared by [`Self::serve`] and the
    /// readiness-loop server.
    pub fn dispatch(&self, msg: &[u8]) -> Result<Vec<u8>> {
        ensure!(msg.len() >= 8, "short wire message");
        let kind = u32::from_le_bytes(msg[4..8].try_into().unwrap());
        match self.handlers.get(&kind) {
            Some(h) => h(msg),
            None => bail!("no handler for kind {kind}"),
        }
    }

    /// Unframe a request, dispatch it, and re-frame the response under the
    /// request's correlation id — one full request lifecycle, minus I/O.
    pub fn dispatch_frame(&self, req: &[u8]) -> Result<Vec<u8>> {
        let (corr, msg) = unframe(req)?;
        Ok(frame(corr, &self.dispatch(msg)?))
    }

    /// Serve one connection until the peer disconnects or `stop` is set.
    pub fn serve<T: Transport>(&self, transport: &T) -> Result<()> {
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            let req = match transport.recv() {
                Ok(f) => f,
                Err(_) => return Ok(()), // disconnect = normal shutdown
            };
            transport.send(self.dispatch_frame(&req)?)?;
        }
    }
}

/// RPC client over a transport (single outstanding request per call;
/// the trainer pipelines by using one client per in-flight stream).
pub struct RpcClient<T: Transport> {
    transport: T,
    next_corr: AtomicU64,
}

impl<T: Transport> RpcClient<T> {
    pub fn new(transport: T) -> Self {
        Self { transport, next_corr: AtomicU64::new(1) }
    }

    /// Send a wire message; block for the matching response.
    pub fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        let corr = self.next_corr.fetch_add(1, Ordering::Relaxed);
        self.transport.send(frame(corr, msg))?;
        loop {
            let resp = self.transport.recv()?;
            let (rcorr, body) = unframe(&resp)?;
            if rcorr == corr {
                return Ok(body.to_vec());
            }
            // Out-of-order response for a different stream: ignore (callers
            // serialize per-client, so this only happens after errors).
        }
    }
}

// ---------------------------------------------------------------------------
// Pipelined TCP client
// ---------------------------------------------------------------------------

/// Frames larger than this are a protocol error (matches the transport
/// layer's bound).
const MAX_FRAME: usize = 1 << 30;

/// How often the background reader re-checks the dead flag while idle.
const READER_POLL: Duration = Duration::from_millis(200);

/// Mutable completion state shared between callers and the reader thread.
struct PipeState {
    /// Demuxed responses, keyed by correlation id, awaiting their caller.
    replies: HashMap<u64, Vec<u8>>,
    /// Requests written whose replies have not yet arrived — the quantity
    /// the window bounds. Freed by the *reader* on arrival (not by the
    /// claiming waiter), so a caller can issue more async requests than
    /// the window and drain them later without deadlocking itself.
    inflight: usize,
    /// Correlation ids whose waiter gave up before the reply arrived; the
    /// reader drops these replies instead of leaking them into the map.
    abandoned: std::collections::HashSet<u64>,
    /// First fatal error; once set, every current and future call fails.
    dead: Option<String>,
}

/// Handed to the reader thread separately from [`PipeInner`], so dropping
/// the last client handle can shut the socket down and terminate the
/// reader (which would otherwise keep the connection alive forever).
struct PipeShared {
    state: Mutex<PipeState>,
    cv: Condvar,
}

impl PipeShared {
    fn mark_dead(&self, why: &str) {
        {
            let mut st = lock_unpoisoned(&self.state);
            if st.dead.is_none() {
                st.dead = Some(why.to_string());
            }
        }
        self.cv.notify_all();
    }
}

struct PipeInner {
    writer: Mutex<TcpStream>,
    shared: Arc<PipeShared>,
    next_corr: AtomicU64,
    window: usize,
    io_timeout: Option<Duration>,
}

impl PipeInner {
    /// Kill the connection: poison-free dead-marking plus a socket shutdown
    /// so the reader thread and any blocked peer writes unwind promptly.
    fn fail(&self, why: &str) {
        self.shared.mark_dead(why);
        let _ = lock_unpoisoned(&self.writer).shutdown(Shutdown::Both);
    }

    fn wait_locked<'a>(
        &self,
        st: MutexGuard<'a, PipeState>,
        deadline: Option<Instant>,
    ) -> Result<MutexGuard<'a, PipeState>> {
        match deadline {
            None => Ok(self
                .shared
                .cv
                .wait(st)
                .unwrap_or_else(|poisoned| poisoned.into_inner())),
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    drop(st);
                    let why = format!(
                        "rpc deadline exceeded ({:?}) — peer accepted but never answered",
                        self.io_timeout.unwrap_or_default()
                    );
                    self.fail(&why);
                    bail!("{why}");
                }
                Ok(self
                    .shared
                    .cv
                    .wait_timeout(st, d - now)
                    .unwrap_or_else(|poisoned| poisoned.into_inner())
                    .0)
            }
        }
    }

    /// Claim the response for `corr`, blocking until it arrives, the
    /// connection dies, or the I/O deadline passes.
    fn wait(&self, corr: u64) -> Result<Vec<u8>> {
        let deadline = self.io_timeout.map(|t| Instant::now() + t);
        let mut st = lock_unpoisoned(&self.shared.state);
        loop {
            if let Some(resp) = st.replies.remove(&corr) {
                return Ok(resp);
            }
            if let Some(why) = st.dead.clone() {
                bail!("pipelined rpc connection is dead: {why}");
            }
            st = self.wait_locked(st, deadline)?;
        }
    }

    /// Forget an abandoned request. An already-arrived reply is discarded
    /// now; otherwise the reader discards it (and frees the window slot) on
    /// arrival.
    fn abandon(&self, corr: u64) {
        let mut st = lock_unpoisoned(&self.shared.state);
        if st.replies.remove(&corr).is_none() {
            st.abandoned.insert(corr);
        }
    }
}

impl Drop for PipeInner {
    fn drop(&mut self) {
        // Terminates the reader thread: the dead flag is observed within
        // `READER_POLL`, and the shutdown usually wakes it immediately.
        self.fail("client dropped");
    }
}

/// A response that has been requested but not yet claimed. Dropping it
/// without [`wait`](Self::wait) abandons the request: the reader discards
/// its reply on arrival instead of leaking it into the completion map.
pub struct PendingReply {
    inner: Arc<PipeInner>,
    corr: Option<u64>,
}

impl PendingReply {
    /// Block for this request's response (bounded by the client's I/O
    /// deadline).
    pub fn wait(mut self) -> Result<Vec<u8>> {
        let corr = self.corr.take().expect("PendingReply waited twice");
        self.inner.wait(corr)
    }
}

impl Drop for PendingReply {
    fn drop(&mut self) {
        if let Some(corr) = self.corr.take() {
            self.inner.abandon(corr);
        }
    }
}

/// Pipelined RPC client: up to `window` sequence-tagged requests in flight
/// on one TCP connection, demuxed by a background reader into a completion
/// map. Cheap to clone (all clones share the connection, window, and
/// completion state); [`Self::same_as`] tells clones of the same
/// connection apart from a redialed replacement.
#[derive(Clone)]
pub struct PipelinedClient {
    inner: Arc<PipeInner>,
}

impl PipelinedClient {
    /// Dial `addr` and start the reader. `window` bounds concurrent
    /// in-flight requests; `io_timeout` bounds every socket write and every
    /// response wait (`None` = wait forever, the pre-deadline behavior).
    pub fn connect(
        addr: &str,
        window: usize,
        io_timeout: Option<Duration>,
    ) -> Result<PipelinedClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("dialing pipelined rpc endpoint {addr}"))?;
        Self::from_stream(stream, window, io_timeout)
    }

    /// Wrap an already-connected stream (loopback tests, custom dialers).
    pub fn from_stream(
        stream: TcpStream,
        window: usize,
        io_timeout: Option<Duration>,
    ) -> Result<PipelinedClient> {
        ensure!(window >= 1, "pipeline window must be >= 1, got {window}");
        stream.set_nodelay(true).ok();
        // Bound writes at the socket; reads are bounded per-wait instead
        // (a short socket read timeout would tear partial frames apart).
        stream.set_write_timeout(io_timeout).context("setting rpc write timeout")?;
        let reader_stream = stream.try_clone().context("cloning pipelined rpc stream")?;
        reader_stream
            .set_read_timeout(Some(READER_POLL))
            .context("setting rpc reader poll interval")?;
        let shared = Arc::new(PipeShared {
            state: Mutex::new(PipeState {
                replies: HashMap::new(),
                inflight: 0,
                abandoned: std::collections::HashSet::new(),
                dead: None,
            }),
            cv: Condvar::new(),
        });
        let reader_shared = shared.clone();
        std::thread::Builder::new()
            .name("rpc-pipeline-reader".to_string())
            .spawn(move || reader_loop(reader_stream, &reader_shared))
            .context("spawning rpc pipeline reader")?;
        Ok(PipelinedClient {
            inner: Arc::new(PipeInner {
                writer: Mutex::new(stream),
                shared,
                next_corr: AtomicU64::new(1),
                window,
                io_timeout,
            }),
        })
    }

    /// Do `self` and `other` share one underlying connection?
    pub fn same_as(&self, other: &PipelinedClient) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// The configured in-flight window.
    pub fn window(&self) -> usize {
        self.inner.window
    }

    /// Acquire a window slot (blocking while `window` requests are in
    /// flight) and write one framed request. Returns the correlation id.
    fn send(&self, msg: &[u8]) -> Result<u64> {
        let inner = &self.inner;
        let deadline = inner.io_timeout.map(|t| Instant::now() + t);
        {
            let mut st = lock_unpoisoned(&inner.shared.state);
            loop {
                if let Some(why) = &st.dead {
                    bail!("pipelined rpc connection is dead: {why}");
                }
                if st.inflight < inner.window {
                    break;
                }
                st = inner.wait_locked(st, deadline)?;
            }
            st.inflight += 1;
        }
        let corr = inner.next_corr.fetch_add(1, Ordering::Relaxed);
        let framed = frame(corr, msg);
        let write = {
            let mut w = lock_unpoisoned(&inner.writer);
            w.write_all(&(framed.len() as u32).to_le_bytes())
                .and_then(|()| w.write_all(&framed))
        };
        if let Err(e) = write {
            inner.abandon(corr);
            let why = format!("write failed: {e}");
            inner.fail(&why);
            bail!("pipelined rpc {why}");
        }
        Ok(corr)
    }

    /// Send a wire message; block for the matching response (bounded by the
    /// I/O deadline). Clones of this client may call concurrently — their
    /// requests interleave on the wire up to the window.
    pub fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        let corr = self.send(msg)?;
        self.inner.wait(corr)
    }

    /// Send a wire message and return immediately with a completion handle;
    /// the response is claimed by [`PendingReply::wait`], in any order
    /// relative to other in-flight requests.
    pub fn call_async(&self, msg: &[u8]) -> Result<PendingReply> {
        let corr = self.send(msg)?;
        Ok(PendingReply { inner: self.inner.clone(), corr: Some(corr) })
    }
}

/// The background demux loop: accumulate bytes (partial-read safe), peel
/// complete `[len][corr][msg]` frames, file responses by correlation id.
fn reader_loop(mut stream: TcpStream, shared: &PipeShared) {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = vec![0u8; 64 * 1024];
    loop {
        if lock_unpoisoned(&shared.state).dead.is_some() {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                shared.mark_dead("connection closed by server");
                return;
            }
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if let Err(why) = drain_reply_frames(&mut buf, shared) {
                    shared.mark_dead(&why);
                    return;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => {
                shared.mark_dead(&format!("read failed: {e}"));
                return;
            }
        }
    }
}

/// Peel every complete frame out of `buf` into the completion map.
fn drain_reply_frames(buf: &mut Vec<u8>, shared: &PipeShared) -> std::result::Result<(), String> {
    loop {
        if buf.len() < 4 {
            return Ok(());
        }
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME {
            return Err(format!("oversized rpc frame ({len} bytes)"));
        }
        if buf.len() < 4 + len {
            return Ok(());
        }
        let (corr, body) = match unframe(&buf[4..4 + len]) {
            Ok(x) => x,
            Err(e) => return Err(format!("malformed rpc frame: {e}")),
        };
        {
            let mut st = lock_unpoisoned(&shared.state);
            // The reply is here, so the request no longer occupies the
            // wire: free its window slot whether or not anyone still
            // wants the payload.
            st.inflight = st.inflight.saturating_sub(1);
            if !st.abandoned.remove(&corr) {
                st.replies.insert(corr, body.to_vec());
            }
        }
        shared.cv.notify_all();
        buf.drain(..4 + len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::{ChannelTransport, TcpTransport};
    use crate::comm::wire::{WireReader, WireWriter};
    use std::net::TcpListener;

    #[test]
    fn echo_rpc_roundtrip() {
        let (server_t, client_t) = ChannelTransport::pair();
        let mut server = RpcServer::new();
        server.register(
            5,
            Box::new(|msg| {
                let r = WireReader::parse(msg)?;
                let xs = r.f32(0)?;
                let doubled: Vec<f32> = xs.iter().map(|x| x * 2.0).collect();
                let mut w = WireWriter::new(5);
                w.put_f32(&doubled);
                Ok(w.finish())
            }),
        );
        let handle = std::thread::spawn(move || server.serve(&server_t).unwrap());

        let client = RpcClient::new(client_t);
        let mut w = WireWriter::new(5);
        w.put_f32(&[1.0, 2.0]);
        let resp = client.call(&w.finish()).unwrap();
        let r = WireReader::parse(&resp).unwrap();
        assert_eq!(r.f32(0).unwrap(), vec![2.0, 4.0]);
        drop(client);
        handle.join().unwrap();
    }

    #[test]
    fn unknown_kind_errors_server_side() {
        let (server_t, client_t) = ChannelTransport::pair();
        let server = RpcServer::new();
        let handle = std::thread::spawn(move || server.serve(&server_t));
        let client = RpcClient::new(client_t);
        let w = WireWriter::new(99);
        // Server errors out and drops the connection; the call fails.
        assert!(client.call(&w.finish()).is_err());
        assert!(handle.join().unwrap().is_err());
    }

    #[test]
    fn sequential_calls_share_connection() {
        let (server_t, client_t) = ChannelTransport::pair();
        let mut server = RpcServer::new();
        server.register(1, Box::new(|msg| Ok(msg.to_vec())));
        let handle = std::thread::spawn(move || server.serve(&server_t).unwrap());
        let client = RpcClient::new(client_t);
        for i in 0..10u64 {
            let mut w = WireWriter::new(1);
            w.put_u64(&[i]);
            let resp = client.call(&w.finish()).unwrap();
            let r = WireReader::parse(&resp).unwrap();
            assert_eq!(r.u64(0).unwrap(), vec![i]);
        }
        drop(client);
        handle.join().unwrap();
    }

    #[test]
    fn dispatch_frame_preserves_correlation_id() {
        let mut server = RpcServer::new();
        server.register(1, Box::new(|msg| Ok(msg.to_vec())));
        let mut w = WireWriter::new(1);
        w.put_u64(&[9]);
        let req = frame(1234, &w.finish());
        let resp = server.dispatch_frame(&req).unwrap();
        let (corr, body) = unframe(&resp).unwrap();
        assert_eq!(corr, 1234);
        let r = WireReader::parse(body).unwrap();
        assert_eq!(r.u64(0).unwrap(), vec![9]);
        // Unknown kind surfaces as a dispatch error.
        assert!(server.dispatch_frame(&frame(1, &WireWriter::new(7).finish())).is_err());
    }

    /// An echo server over real TCP (thread-per-connection, good enough to
    /// exercise the client side of pipelining).
    fn tcp_echo_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            while let Ok((stream, _)) = listener.accept() {
                std::thread::spawn(move || {
                    let mut server = RpcServer::new();
                    server.register(1, Box::new(|msg| Ok(msg.to_vec())));
                    let _ = server.serve(&TcpTransport::new(stream));
                });
            }
        });
        (addr, handle)
    }

    #[test]
    fn pipelined_client_completes_out_of_order_waits() {
        let (addr, _server) = tcp_echo_server();
        let client =
            PipelinedClient::connect(&addr.to_string(), 16, Some(Duration::from_secs(30)))
                .unwrap();
        let pending: Vec<PendingReply> = (0..10u64)
            .map(|i| {
                let mut w = WireWriter::new(1);
                w.put_u64(&[i]);
                client.call_async(&w.finish()).unwrap()
            })
            .collect();
        // Claim completions in reverse — the completion map, not response
        // order, routes each reply to its caller.
        for (i, p) in pending.into_iter().enumerate().rev() {
            let resp = p.wait().unwrap();
            let r = WireReader::parse(&resp).unwrap();
            assert_eq!(r.u64(0).unwrap(), vec![i as u64]);
        }
        // The window fully recycles: plain calls still work afterwards.
        let mut w = WireWriter::new(1);
        w.put_u64(&[77]);
        let resp = client.call(&w.finish()).unwrap();
        assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![77]);
    }

    #[test]
    fn pipelined_clones_share_window_and_connection() {
        let (addr, _server) = tcp_echo_server();
        let client =
            PipelinedClient::connect(&addr.to_string(), 8, Some(Duration::from_secs(30)))
                .unwrap();
        let clone = client.clone();
        assert!(client.same_as(&clone));
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let c = clone.clone();
                std::thread::spawn(move || {
                    for i in 0..25u64 {
                        let mut w = WireWriter::new(1);
                        w.put_u64(&[t * 1000 + i]);
                        let resp = c.call(&w.finish()).unwrap();
                        let r = WireReader::parse(&resp).unwrap();
                        assert_eq!(r.u64(0).unwrap(), vec![t * 1000 + i]);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn stalled_server_errors_within_deadline_instead_of_hanging() {
        // A server that accepts and then never answers: the bug this layer
        // fixes is the trainer hanging forever on exactly this peer.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stall = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            std::thread::sleep(Duration::from_secs(10));
            drop(stream);
        });
        let client = PipelinedClient::connect(
            &addr.to_string(),
            4,
            Some(Duration::from_millis(300)),
        )
        .unwrap();
        let t0 = Instant::now();
        let err = client.call(&WireWriter::new(1).finish()).unwrap_err();
        let elapsed = t0.elapsed();
        assert!(
            format!("{err:#}").contains("deadline"),
            "error must cite the deadline: {err:#}"
        );
        assert!(
            elapsed >= Duration::from_millis(250) && elapsed < Duration::from_secs(5),
            "expected ~300ms deadline, took {elapsed:?}"
        );
        // The connection is dead for every subsequent call, immediately.
        assert!(client.call(&WireWriter::new(1).finish()).is_err());
        drop(client);
        stall.join().unwrap();
    }

    #[test]
    fn dropped_pending_reply_releases_its_window_slot() {
        let (addr, _server) = tcp_echo_server();
        let client =
            PipelinedClient::connect(&addr.to_string(), 2, Some(Duration::from_secs(10)))
                .unwrap();
        for _ in 0..10 {
            let mut w = WireWriter::new(1);
            w.put_u64(&[1]);
            // Window is 2: each iteration only proceeds because arriving
            // echo replies free their slots even though every handle is
            // dropped unclaimed — abandoned replies must be discarded, not
            // leaked into the completion map or left occupying the window.
            let _abandoned = client.call_async(&w.finish()).unwrap();
        }
        let mut w = WireWriter::new(1);
        w.put_u64(&[5]);
        let resp = client.call(&w.finish()).unwrap();
        assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![5]);
    }
}
