//! Embedding-traffic compression (paper §4.2.3, "Communication compression").
//!
//! * **Lossless index compression**: a batch's ID component is sent as a map
//!   `unique id -> uint16 sample indices` instead of per-sample int64 lists.
//!   Since batch size <= 65535, indices fit u16 with no information loss; hot
//!   ids that repeat across a batch are transmitted once.
//! * **Lossy value compression**: non-uniform fp32 -> fp16. A uniform cast
//!   loses accuracy, so each vector block `v` is scaled by `kappa/||v||_inf`
//!   before the cast, and rescaled after — keeping the mantissa where the
//!   signal lives regardless of dynamic range. This mirrors the L1 Pallas
//!   `compress` kernel bit-for-bit (same kappa), which serves as its
//!   executable specification.

use crate::data::Batch;
use crate::tensor::{f16_to_f32, f32_to_f16};

/// Must match python/compile/kernels/compress.py.
pub const KAPPA: f32 = 60000.0;

/// Lossless batch index map: `(group, id) -> sample rows`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IndexMap {
    /// Sorted unique (group, id) keys.
    pub keys: Vec<(u32, u64)>,
    /// Concatenated u16 row indices.
    pub rows: Vec<u16>,
    /// Offsets into `rows` per key (len = keys.len() + 1).
    pub offsets: Vec<u32>,
    /// Original batch size.
    pub batch: u16,
    /// Number of feature groups.
    pub n_groups: u32,
}

impl IndexMap {
    /// Build the compressed representation of a batch's ID component.
    pub fn from_batch(batch: &Batch) -> Self {
        assert!(batch.len() <= u16::MAX as usize, "batch too large for u16 indices");
        let uniq = batch.unique_ids();
        let mut keys = Vec::with_capacity(uniq.len());
        let mut rows = Vec::new();
        let mut offsets = Vec::with_capacity(uniq.len() + 1);
        offsets.push(0u32);
        for ((g, id), rs) in uniq {
            keys.push((g as u32, id));
            rows.extend_from_slice(&rs);
            offsets.push(rows.len() as u32);
        }
        let n_groups = batch.ids.first().map(|f| f.groups.len()).unwrap_or(0) as u32;
        Self { keys, rows, offsets, batch: batch.len() as u16, n_groups }
    }

    /// Reconstruct the per-sample id lists (inverse transform; proves
    /// losslessness). Returns `ids[sample][group] -> Vec<id>`.
    pub fn decompress(&self) -> Vec<Vec<Vec<u64>>> {
        let mut out = vec![vec![Vec::new(); self.n_groups as usize]; self.batch as usize];
        for (k, &(g, id)) in self.keys.iter().enumerate() {
            let lo = self.offsets[k] as usize;
            let hi = self.offsets[k + 1] as usize;
            for &row in &self.rows[lo..hi] {
                out[row as usize][g as usize].push(id);
            }
        }
        out
    }

    /// Wire size in bytes of the compressed form.
    pub fn wire_bytes(&self) -> usize {
        self.keys.len() * 12 + self.rows.len() * 2 + self.offsets.len() * 4 + 8
    }

    /// Wire size of the naive per-sample int64 representation.
    pub fn naive_bytes(&self) -> usize {
        self.rows.len() * 8
    }

    /// Compression ratio vs naive int64 lists ( > 1 means smaller ).
    pub fn ratio(&self) -> f64 {
        self.naive_bytes() as f64 / self.wire_bytes().max(1) as f64
    }
}

/// Lossy-compressed value block: per-row fp16 payload + per-row scale.
#[derive(Clone, Debug)]
pub struct CompressedValues {
    /// fp16 bit patterns, row-major `[rows, dim]`.
    pub vals: Vec<u16>,
    /// Per-row decompression factor `||v||_inf / kappa`.
    pub scales: Vec<f32>,
    pub dim: usize,
}

impl CompressedValues {
    /// Compress `rows x dim` f32 values (rows = vector blocks).
    pub fn compress(values: &[f32], dim: usize) -> Self {
        assert!(dim > 0 && values.len() % dim == 0);
        let rows = values.len() / dim;
        let mut vals = Vec::with_capacity(values.len());
        let mut scales = Vec::with_capacity(rows);
        for r in 0..rows {
            let v = &values[r * dim..(r + 1) * dim];
            let norm = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let safe = if norm > 0.0 { norm } else { 1.0 };
            let s = KAPPA / safe;
            for &x in v {
                vals.push(f32_to_f16(x * s));
            }
            scales.push(norm / KAPPA);
        }
        Self { vals, scales, dim }
    }

    /// Decompress back to f32.
    pub fn decompress(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.vals.len());
        for (r, &scale) in self.scales.iter().enumerate() {
            for &h in &self.vals[r * self.dim..(r + 1) * self.dim] {
                out.push(f16_to_f32(h) * scale);
            }
        }
        out
    }

    /// Decompress into a caller-provided buffer (hot path, no allocation).
    pub fn decompress_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.vals.len());
        for (r, &scale) in self.scales.iter().enumerate() {
            let dst = &mut out[r * self.dim..(r + 1) * self.dim];
            let src = &self.vals[r * self.dim..(r + 1) * self.dim];
            for (o, &h) in dst.iter_mut().zip(src) {
                *o = f16_to_f32(h) * scale;
            }
        }
    }

    pub fn wire_bytes(&self) -> usize {
        self.vals.len() * 2 + self.scales.len() * 4
    }

    pub fn uncompressed_bytes(&self) -> usize {
        self.vals.len() * 4
    }
}

/// Worst-case absolute round-trip error of one row: `||v||_inf * 2^-10`
/// (fp16 resolution at the scaled magnitude, plus rounding guard).
pub fn lossy_error_bound(inf_norm: f32) -> f32 {
    inf_norm * 2.0f32.powi(-10) + 1e-30
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{IdFeatures, Sample};
    use crate::util::quickcheck::forall;
    use crate::util::Rng;

    fn batch_with(ids: Vec<Vec<Vec<u64>>>) -> Batch {
        let mut b = Batch::default();
        for groups in ids {
            b.push(Sample { ids: IdFeatures { groups }, nid: vec![0.0], label: 0.0 });
        }
        b
    }

    #[test]
    fn index_map_roundtrips() {
        let ids = vec![
            vec![vec![5, 7], vec![100]],
            vec![vec![5], vec![100, 200]],
            vec![vec![9], vec![]],
        ];
        let b = batch_with(ids.clone());
        let m = IndexMap::from_batch(&b);
        // Decompressed lists contain the same multiset per (sample, group).
        let back = m.decompress();
        for (s, groups) in ids.iter().enumerate() {
            for (g, want) in groups.iter().enumerate() {
                let mut got = back[s][g].clone();
                let mut want = want.clone();
                got.sort_unstable();
                want.sort_unstable();
                assert_eq!(got, want, "sample {s} group {g}");
            }
        }
    }

    #[test]
    fn index_map_shrinks_skewed_batches() {
        // One hot id repeated in every sample: 8-byte int64 each naive,
        // 2-byte u16 each compressed.
        let ids: Vec<_> = (0..256).map(|_| vec![vec![42u64]]).collect();
        let m = IndexMap::from_batch(&batch_with(ids));
        assert_eq!(m.keys.len(), 1);
        assert!(m.ratio() > 3.0, "ratio={}", m.ratio());
    }

    #[test]
    fn property_index_map_lossless() {
        forall(
            31,
            100,
            |rng: &mut Rng| {
                let b = rng.range(1, 20) as usize;
                (0..b)
                    .map(|_| {
                        (0..2)
                            .map(|_| {
                                (0..rng.below(4)).map(|_| rng.below(50)).collect::<Vec<u64>>()
                            })
                            .collect::<Vec<_>>()
                    })
                    .collect::<Vec<_>>()
            },
            |ids| {
                let m = IndexMap::from_batch(&batch_with(ids.clone()));
                let back = m.decompress();
                ids.iter().enumerate().all(|(s, groups)| {
                    groups.iter().enumerate().all(|(g, want)| {
                        let mut got = back[s][g].clone();
                        let mut want = want.clone();
                        got.sort_unstable();
                        want.sort_unstable();
                        got == want
                    })
                })
            },
        );
    }

    #[test]
    fn values_roundtrip_within_bound() {
        let mut rng = Rng::new(5);
        for scale in [1e-6f32, 1.0, 1e4, 1e8] {
            let dim = 16;
            let vals: Vec<f32> = (0..dim * 8).map(|_| rng.normal() * scale).collect();
            let c = CompressedValues::compress(&vals, dim);
            let back = c.decompress();
            for r in 0..8 {
                let row = &vals[r * dim..(r + 1) * dim];
                let norm = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                let bound = lossy_error_bound(norm);
                for (a, b) in row.iter().zip(&back[r * dim..(r + 1) * dim]) {
                    assert!((a - b).abs() <= bound, "{a} vs {b} bound {bound}");
                }
            }
        }
    }

    #[test]
    fn values_zero_rows_exact() {
        let c = CompressedValues::compress(&[0.0; 12], 4);
        assert_eq!(c.decompress(), vec![0.0; 12]);
    }

    #[test]
    fn values_halve_wire_size() {
        let c = CompressedValues::compress(&vec![1.0f32; 128 * 16], 16);
        let ratio = c.uncompressed_bytes() as f64 / c.wire_bytes() as f64;
        assert!(ratio > 1.7, "ratio={ratio}");
    }

    #[test]
    fn decompress_into_matches_alloc_version() {
        let mut rng = Rng::new(6);
        let vals: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let c = CompressedValues::compress(&vals, 8);
        let mut buf = vec![0.0f32; 64];
        c.decompress_into(&mut buf);
        assert_eq!(buf, c.decompress());
    }
}
