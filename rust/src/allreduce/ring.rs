//! Ring AllReduce across in-process participants (one per NN-worker thread).
//!
//! Standard two-phase ring: K-1 reduce-scatter steps then K-1 all-gather
//! steps over K chunks; each participant sends `2*(K-1)/K * N` elements per
//! reduction — the bandwidth-optimal schedule. Simulated GPU-GPU wire time is
//! accounted against [`NetSim`] per step so the Gantt/throughput experiments
//! see realistic AllReduce costs.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::comm::netsim::{Link, NetSim};

/// The element range of chunk `c` when `n` elements are split into `k`
/// near-equal chunks (the first `n % k` chunks get one extra element).
///
/// Shared by the in-process [`RingMember`], the TCP
/// [`TcpRingMember`](super::tcp_ring::TcpRingMember), and
/// [`reference_sum`], so every ring implementation provably runs the same
/// schedule — which is what makes them bit-identical to each other.
pub fn chunk_range(n: usize, k: usize, c: usize) -> std::ops::Range<usize> {
    let base = n / k;
    let rem = n % k;
    let start = c * base + c.min(rem);
    let len = base + usize::from(c < rem);
    start..start + len
}

/// Serial replay of the ring's deterministic reduction order: chunk `c` is
/// accumulated left-associated in ring order starting at rank `c`
/// (`((x_c + x_{c+1}) + x_{c+2}) + ...`, wrapping mod `k`) — exactly the
/// association the reduce-scatter phase produces. Every ring member (thread
/// or TCP, any rank) returns this value bit-for-bit when summing.
pub fn reference_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
    let k = inputs.len();
    assert!(k >= 1);
    let n = inputs[0].len();
    let mut out = inputs[0].clone();
    if k == 1 {
        return out;
    }
    for c in 0..k {
        let r = chunk_range(n, k, c);
        out[r.clone()].copy_from_slice(&inputs[c][r.clone()]);
        for hop in 1..k {
            let j = (c + hop) % k;
            assert_eq!(inputs[j].len(), n, "ragged ring inputs");
            for (a, &b) in out[r.clone()].iter_mut().zip(&inputs[j][r.clone()]) {
                // Mirrors `buf[own] += incoming` at each hop; IEEE addition
                // is commutative, so the bits match either way.
                *a = b + *a;
            }
        }
    }
    out
}

/// [`reference_sum`] followed by the same `* (1/k)` the members apply.
pub fn reference_mean(inputs: &[Vec<f32>]) -> Vec<f32> {
    let mut out = reference_sum(inputs);
    let inv = 1.0 / inputs.len() as f32;
    for x in out.iter_mut() {
        *x *= inv;
    }
    out
}

/// One participant's handle into a ring group.
pub struct RingMember {
    rank: usize,
    k: usize,
    /// Send to successor rank.
    tx: Sender<Vec<f32>>,
    /// Receive from predecessor rank.
    rx: Receiver<Vec<f32>>,
    net: Arc<NetSim>,
}

/// Factory for a K-member ring.
pub struct RingGroup;

impl RingGroup {
    /// Create `k` connected members (rank i sends to rank (i+1) % k).
    pub fn new(k: usize, net: Arc<NetSim>) -> Vec<RingMember> {
        assert!(k >= 1);
        let mut txs = Vec::with_capacity(k);
        let mut rxs = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        // Member i receives from channel i (its predecessor writes there) and
        // sends into channel (i+1) % k.
        let mut members: Vec<RingMember> = Vec::with_capacity(k);
        rxs.reverse();
        for (i, _) in txs.iter().enumerate() {
            members.push(RingMember {
                rank: i,
                k,
                tx: txs[(i + 1) % k].clone(),
                rx: rxs.pop().unwrap(),
                net: net.clone(),
            });
        }
        members
    }
}

impl RingMember {
    /// This member's rank in `0..world`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ring members.
    pub fn world(&self) -> usize {
        self.k
    }

    /// Pass the ordering token to the successor rank. Tokens ride the same
    /// FIFO links as AllReduce chunks (as a zero-length payload), so a
    /// strictly phased caller — everyone alternates token sections and
    /// AllReduces in the same program order — never confuses the two.
    pub fn send_token(&self) -> anyhow::Result<()> {
        if self.k == 1 {
            return Ok(());
        }
        self.tx
            .send(Vec::new())
            .map_err(|_| anyhow::anyhow!("ring successor disconnected"))
    }

    /// Receive the ordering token from the predecessor rank.
    pub fn recv_token(&self) -> anyhow::Result<()> {
        if self.k == 1 {
            return Ok(());
        }
        let frame = self
            .rx
            .recv()
            .map_err(|_| anyhow::anyhow!("ring predecessor disconnected"))?;
        anyhow::ensure!(
            frame.is_empty(),
            "ring desynchronized: expected an ordering token, got a {}-element chunk",
            frame.len()
        );
        Ok(())
    }

    /// In-place AllReduce (mean) over all members' `buf` (equal lengths).
    /// Returns the simulated communication seconds spent by this member.
    pub fn all_reduce_mean(&self, buf: &mut [f32]) -> f64 {
        let sim = self.all_reduce_sum(buf);
        let inv = 1.0 / self.k as f32;
        for x in buf.iter_mut() {
            *x *= inv;
        }
        sim
    }

    /// In-place AllReduce (sum). Returns simulated comm seconds.
    pub fn all_reduce_sum(&self, buf: &mut [f32]) -> f64 {
        let k = self.k;
        if k == 1 {
            return 0.0;
        }
        let n = buf.len();
        let chunk = |c: usize| chunk_range(n, k, c);
        let mut sim_secs = 0.0;

        // Phase 1: reduce-scatter. After step s, each member owns the full
        // sum of chunk (rank - s) (mod k)... standard schedule:
        for s in 0..k - 1 {
            let send_c = (self.rank + k - s) % k;
            let recv_c = (self.rank + k - s - 1) % k;
            let payload = buf[chunk(send_c)].to_vec();
            sim_secs += self.net.record(Link::GpuGpu, payload.len() * 4);
            self.tx.send(payload).expect("ring peer alive");
            let incoming = self.rx.recv().expect("ring peer alive");
            let r = chunk(recv_c);
            debug_assert_eq!(incoming.len(), r.len());
            for (a, b) in buf[r].iter_mut().zip(&incoming) {
                *a += b;
            }
        }
        // Phase 2: all-gather the reduced chunks around the ring.
        for s in 0..k - 1 {
            let send_c = (self.rank + 1 + k - s) % k;
            let recv_c = (self.rank + k - s) % k;
            let payload = buf[chunk(send_c)].to_vec();
            sim_secs += self.net.record(Link::GpuGpu, payload.len() * 4);
            self.tx.send(payload).expect("ring peer alive");
            let incoming = self.rx.recv().expect("ring peer alive");
            let r = chunk(recv_c);
            buf[r].copy_from_slice(&incoming);
        }
        sim_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetModelConfig;
    use crate::util::Rng;

    fn run_ring(k: usize, n: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let members = RingGroup::new(k, net);
        let mut rng = Rng::new(seed);
        let inputs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(n)).collect();
        let mut want = vec![0.0f32; n];
        for input in &inputs {
            for (w, x) in want.iter_mut().zip(input) {
                *w += x;
            }
        }
        for w in want.iter_mut() {
            *w /= k as f32;
        }
        let handles: Vec<_> = members
            .into_iter()
            .zip(inputs.clone())
            .map(|(m, mut buf)| {
                std::thread::spawn(move || {
                    m.all_reduce_mean(&mut buf);
                    buf
                })
            })
            .collect();
        let outputs: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (outputs, want)
    }

    #[test]
    fn allreduce_mean_matches_direct_mean() {
        for k in [1usize, 2, 3, 4, 7] {
            for n in [1usize, 5, 64, 257] {
                if n < k {
                    continue;
                }
                let (outputs, want) = run_ring(k, n, (k * 1000 + n) as u64);
                for out in &outputs {
                    for (a, b) in out.iter().zip(&want) {
                        assert!((a - b).abs() < 1e-4, "k={k} n={n}: {a} vs {b}");
                    }
                }
            }
        }
    }

    #[test]
    fn uneven_chunks_handled() {
        // n not divisible by k exercises the remainder chunks.
        let (outputs, want) = run_ring(3, 10, 9);
        for out in &outputs {
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn single_member_is_identity() {
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let members = RingGroup::new(1, net);
        let mut buf = vec![1.0, 2.0, 3.0];
        let secs = members[0].all_reduce_mean(&mut buf);
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        assert_eq!(secs, 0.0);
    }

    #[test]
    fn reference_replays_ring_reduction_bit_exactly() {
        for k in [1usize, 2, 3, 5, 8] {
            for n in [1usize, 4, 63, 200] {
                let seed = (k * 31 + n) as u64;
                let (outputs, _) = run_ring(k, n, seed);
                // Regenerate the exact inputs run_ring fed the members.
                let mut rng = Rng::new(seed);
                let inputs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(n)).collect();
                let want = reference_mean(&inputs);
                for out in &outputs {
                    assert_eq!(out, &want, "k={k} n={n}: ring != reference replay");
                }
            }
        }
    }

    #[test]
    fn chunk_ranges_partition_exactly() {
        for k in 1..9usize {
            for n in [0usize, 1, 3, 8, 17, 100] {
                let mut covered = 0;
                for c in 0..k {
                    let r = chunk_range(n, k, c);
                    assert_eq!(r.start, covered, "k={k} n={n} c={c}");
                    covered = r.end;
                }
                assert_eq!(covered, n, "k={k} n={n}");
            }
        }
    }

    #[test]
    fn token_cycle_orders_ranks() {
        // Tokens serialize a critical section in rank order: rank 0 runs,
        // passes the token, each rank appends, and rank 0 absorbs the
        // fully-cycled token — the deterministic-mode PS ordering.
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let k = 4;
        let members = RingGroup::new(k, net);
        let log = Arc::new(std::sync::Mutex::new(Vec::new()));
        let handles: Vec<_> = members
            .into_iter()
            .map(|m| {
                let log = log.clone();
                std::thread::spawn(move || {
                    for _round in 0..3 {
                        if m.rank() > 0 {
                            m.recv_token().unwrap();
                        }
                        log.lock().unwrap().push(m.rank());
                        m.send_token().unwrap();
                        if m.rank() == 0 {
                            m.recv_token().unwrap();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn simulated_bytes_are_bandwidth_optimal() {
        let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
        let k = 4;
        let n = 4096;
        let members = RingGroup::new(k, net.clone());
        let handles: Vec<_> = members
            .into_iter()
            .map(|m| {
                std::thread::spawn(move || {
                    let mut buf = vec![1.0f32; 4096];
                    m.all_reduce_sum(&mut buf);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Each member sends 2*(k-1)/k * n floats.
        let per_member = 2 * (k - 1) * n / k * 4;
        let want_total = (per_member * k) as u64;
        let got = net.total_bytes();
        let tolerance = (k * k * 4) as u64; // remainder-chunk rounding
        assert!(got.abs_diff(want_total) <= tolerance, "got={got} want={want_total}");
    }
}
