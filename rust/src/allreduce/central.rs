//! Naive central-PS gradient reduction — the baseline the ring AllReduce is
//! benched against (classic parameter-server dense sync, what the paper's
//! "straightforward utilization of the PS paradigm" §4.1 does for w_nn).
//!
//! Every worker ships its full gradient to rank 0, which reduces and
//! broadcasts back: each non-root pays `2N` elements, the root pays `2N(K-1)`
//! — the centralization bottleneck the ring removes.

use std::sync::Arc;

use crate::comm::netsim::{Link, NetSim};

/// Reduce `grads` (one full-length vector per worker) to their mean, and
/// account the simulated transfer cost of the star topology. Returns
/// (mean gradient, simulated seconds on the critical path).
pub fn central_reduce(grads: &[Vec<f32>], net: &Arc<NetSim>) -> (Vec<f32>, f64) {
    assert!(!grads.is_empty());
    let n = grads[0].len();
    let k = grads.len();
    let mut mean = vec![0.0f32; n];
    for g in grads {
        assert_eq!(g.len(), n, "ragged gradients");
        for (m, x) in mean.iter_mut().zip(g) {
            *m += x;
        }
    }
    let inv = 1.0 / k as f32;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    // Critical path: root receives K-1 gradients serially on its link, then
    // broadcasts K-1 copies (uploads + downloads serialize at the root NIC).
    let mut secs = 0.0;
    for _ in 0..k.saturating_sub(1) {
        secs += net.record(Link::GpuGpu, n * 4); // upload to root
    }
    for _ in 0..k.saturating_sub(1) {
        secs += net.record(Link::GpuGpu, n * 4); // broadcast back
    }
    (mean, secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetModelConfig;

    #[test]
    fn mean_is_exact() {
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let grads = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let (mean, secs) = central_reduce(&grads, &net);
        assert_eq!(mean, vec![3.0, 4.0]);
        assert_eq!(secs, 0.0);
    }

    #[test]
    fn critical_path_scales_linearly_with_workers() {
        let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
        let n = 1 << 16;
        let g2: Vec<Vec<f32>> = (0..2).map(|_| vec![1.0; n]).collect();
        let g8: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0; n]).collect();
        let (_, s2) = central_reduce(&g2, &net);
        let (_, s8) = central_reduce(&g8, &net);
        // (8-1)/(2-1) = 7x the transfers.
        assert!((s8 / s2 - 7.0).abs() < 0.2, "ratio={}", s8 / s2);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_inputs_rejected() {
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        central_reduce(&[vec![1.0], vec![1.0, 2.0]], &net);
    }
}
