//! Tensor bucketing + memory flattening (Bagua's optimization, used here for
//! the dense-gradient AllReduce).
//!
//! Many small per-layer gradient tensors are copied into one (or a few)
//! contiguous flat buffers so the AllReduce runs over large chunks —
//! amortizing per-message latency — and so the reduce loop is a straight
//! SIMD-friendly f32 sweep.

use crate::tensor::Tensor;

/// Flattened view of a list of tensors, split into fixed-size buckets.
pub struct FlatBuckets {
    /// Contiguous storage of all elements in declaration order.
    flat: Vec<f32>,
    /// (offset, len) per original tensor.
    spans: Vec<(usize, usize)>,
    /// Bucket boundaries as (offset, len) into `flat`.
    buckets: Vec<(usize, usize)>,
}

impl FlatBuckets {
    /// Flatten `tensors` with the given bucket size in elements.
    pub fn flatten(tensors: &[Tensor], bucket_elems: usize) -> Self {
        assert!(bucket_elems > 0);
        let total: usize = tensors.iter().map(|t| t.len()).sum();
        let mut flat = Vec::with_capacity(total);
        let mut spans = Vec::with_capacity(tensors.len());
        for t in tensors {
            spans.push((flat.len(), t.len()));
            flat.extend_from_slice(t.data());
        }
        let mut buckets = Vec::new();
        let mut off = 0;
        while off < total {
            let len = bucket_elems.min(total - off);
            buckets.push((off, len));
            off += len;
        }
        Self { flat, spans, buckets }
    }

    /// Number of fixed-size buckets covering the flat buffer.
    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Total f32 elements across every fused tensor.
    pub fn total_elems(&self) -> usize {
        self.flat.len()
    }

    /// The fused flat buffer (tensors back to back).
    pub fn flat(&self) -> &[f32] {
        &self.flat
    }

    /// Mutable view of the fused flat buffer.
    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.flat
    }

    /// Mutable view of bucket `i`.
    pub fn bucket_mut(&mut self, i: usize) -> &mut [f32] {
        let (off, len) = self.buckets[i];
        &mut self.flat[off..off + len]
    }

    /// Copy the (possibly reduced) flat data back into tensors with the
    /// original shapes.
    pub fn unflatten_into(&self, tensors: &mut [Tensor]) {
        assert_eq!(tensors.len(), self.spans.len());
        for (t, &(off, len)) in tensors.iter_mut().zip(&self.spans) {
            assert_eq!(t.len(), len);
            t.data_mut().copy_from_slice(&self.flat[off..off + len]);
        }
    }

    /// Allocate fresh tensors with the given shapes from the flat data.
    pub fn unflatten(&self, shapes: &[Vec<usize>]) -> Vec<Tensor> {
        assert_eq!(shapes.len(), self.spans.len());
        shapes
            .iter()
            .zip(&self.spans)
            .map(|(shape, &(off, len))| {
                assert_eq!(shape.iter().product::<usize>(), len);
                Tensor::from_vec(shape, self.flat[off..off + len].to_vec())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;
    use crate::util::Rng;

    fn tensors(rng: &mut Rng, shapes: &[Vec<usize>]) -> Vec<Tensor> {
        shapes
            .iter()
            .map(|s| Tensor::from_vec(s, rng.normal_vec(s.iter().product())))
            .collect()
    }

    #[test]
    fn flatten_roundtrip() {
        let shapes = vec![vec![3, 4], vec![7], vec![2, 2, 2]];
        let mut rng = Rng::new(1);
        let ts = tensors(&mut rng, &shapes);
        let fb = FlatBuckets::flatten(&ts, 5);
        assert_eq!(fb.total_elems(), 12 + 7 + 8);
        assert_eq!(fb.n_buckets(), (27 + 4) / 5);
        let back = fb.unflatten(&shapes);
        assert_eq!(back, ts);
    }

    #[test]
    fn buckets_cover_exactly_once() {
        let mut rng = Rng::new(2);
        let ts = tensors(&mut rng, &[vec![10], vec![13]]);
        let mut fb = FlatBuckets::flatten(&ts, 4);
        // Zero each bucket once; everything must be zero after.
        for i in 0..fb.n_buckets() {
            for x in fb.bucket_mut(i) {
                *x = 0.0;
            }
        }
        assert!(fb.flat().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn unflatten_into_reuses_storage() {
        let shapes = vec![vec![4], vec![6]];
        let mut rng = Rng::new(3);
        let ts = tensors(&mut rng, &shapes);
        let mut fb = FlatBuckets::flatten(&ts, 100);
        for x in fb.flat_mut() {
            *x *= 2.0;
        }
        let mut out = vec![Tensor::zeros(&[4]), Tensor::zeros(&[6])];
        fb.unflatten_into(&mut out);
        for (o, t) in out.iter().zip(&ts) {
            for (a, b) in o.data().iter().zip(t.data()) {
                assert_eq!(*a, b * 2.0);
            }
        }
    }

    #[test]
    fn property_flatten_preserves_all_elements() {
        forall(
            41,
            100,
            |rng: &mut Rng| {
                let n = rng.range(1, 5) as usize;
                (0..n).map(|_| rng.range(1, 40) as usize).collect::<Vec<usize>>()
            },
            |lens| {
                let mut rng = Rng::new(lens.iter().sum::<usize>() as u64);
                let shapes: Vec<Vec<usize>> = lens.iter().map(|&l| vec![l]).collect();
                let ts = tensors(&mut rng, &shapes);
                let fb = FlatBuckets::flatten(&ts, 7);
                let want: Vec<f32> = ts.iter().flat_map(|t| t.data().to_vec()).collect();
                fb.flat() == want.as_slice() && fb.unflatten(&shapes) == ts
            },
        );
    }
}
