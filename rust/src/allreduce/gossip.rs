//! Best-effort peer-to-peer replica gossip — the cross-process form of
//! FullAsync's periodic re-centering (paper §4.2.2: asynchronous dense
//! updates tolerate drift; the sync primitive must never serialize ranks).
//!
//! Before this module, a multi-process FullAsync run re-centered its dense
//! replicas with a full ring AllReduce — a *barrier*: one slow or stalled
//! rank held every other rank's step hostage, which is exactly the failure
//! mode FullAsync exists to avoid. [`GossipFabric`] replaces the barrier
//! with the same protocol the in-process deployment always had
//! ([`ThreadRing`](crate::hybrid::dense_comm::ThreadRing)'s shared slots),
//! over real sockets:
//!
//! * Every rank binds a gossip listener next to its ring listener; the
//!   addresses travel through the ring rendezvous table, so the fabric
//!   forms with zero extra configuration.
//! * **Posting** a replica is fire-and-forget: the frame is handed to a
//!   per-peer outbox thread through a bounded channel with
//!   [`std::sync::mpsc::SyncSender::try_send`] — if the peer is slow, dead,
//!   or still connecting, the post is *dropped*, never awaited.
//! * **Averaging** folds in whatever each peer most recently posted
//!   (by sequence number); a rank that has posted nothing yet simply does
//!   not participate — identical to the thread deployment's empty slot.
//!
//! Deterministic runs use the acked variant
//! ([`GossipFabric::post_acked_and_average`]): inside a token-ordered
//! section the post *is* awaited (the receiver acknowledges after storing),
//! so the set of replicas each rank averages is a pure function of rank —
//! what makes a deterministic multi-process FullAsync run bit-identical to
//! the threaded one.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::comm::netsim::{Link, NetSim};
use crate::comm::transport::{TcpTransport, Transport};
use crate::comm::wire::{WireReader, WireWriter};
use crate::util::lock_unpoisoned;

/// One replica post: u64 `[rank, seq, want_ack]` + the f32 dense params.
pub const KIND_GOSSIP: u32 = 0x6007;
/// Acknowledgement of a stored post: u64 `[seq]` (acked variant only).
pub const KIND_GOSSIP_ACK: u32 = 0x6008;

/// How long a fire-and-forget outbox thread spends dialing a peer before
/// dropping the post. Generous for loopback/datacenter RTTs, and off the
/// training thread either way.
const ASYNC_DIAL_TIMEOUT: Duration = Duration::from_millis(250);

/// Accept-loop poll granularity (also bounds shutdown latency).
const ACCEPT_POLL: Duration = Duration::from_millis(100);

fn encode_post(rank: usize, seq: u64, want_ack: bool, params: &[f32]) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_GOSSIP);
    w.put_u64(&[rank as u64, seq, u64::from(want_ack)]);
    w.put_f32(params);
    w.finish()
}

fn encode_ack(seq: u64) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_GOSSIP_ACK);
    w.put_u64(&[seq]);
    w.finish()
}

/// Block until `listener` has a pending connection or `dur` elapses —
/// `poll(2)` on unix, a bounded sleep elsewhere.
pub(crate) fn wait_incoming(listener: &TcpListener, dur: Duration) {
    #[cfg(unix)]
    {
        use std::os::unix::io::AsRawFd;
        let _ = crate::comm::poll::poll_readable(listener.as_raw_fd(), dur);
    }
    #[cfg(not(unix))]
    {
        let _ = listener;
        std::thread::sleep(dur.min(Duration::from_millis(5)));
    }
}

/// The latest replica a peer has posted.
type Slot = Mutex<Option<(u64, Vec<f32>)>>;

/// Fan-out links to one peer: the fire-and-forget outbox and the lazily
/// dialed acked connection (deterministic variant only).
struct PeerLink {
    addr: String,
    outbox: SyncSender<Vec<u8>>,
    acked: Mutex<Option<TcpTransport>>,
}

/// One rank's membership in the gossip mesh: a receive side (accept thread
/// + one reader thread per inbound connection, storing the latest post per
/// peer rank) and a send side (one outbox thread per peer).
///
/// Dropping the fabric stops the accept loop and tears down the outboxes;
/// reader threads exit when their peer closes.
pub struct GossipFabric {
    rank: usize,
    world: usize,
    seq: u64,
    slots: Arc<Vec<Slot>>,
    peers: Vec<Option<PeerLink>>,
    timeout: Duration,
    net: Arc<NetSim>,
    stop: Arc<AtomicBool>,
}

impl GossipFabric {
    /// Start the mesh for `rank` of `world`: `listener` is this rank's
    /// pre-bound gossip listener (bound before the rendezvous so its
    /// address could travel in the table), `peer_addrs[r]` is rank `r`'s
    /// gossip address (the own-rank entry is ignored), and `timeout` bounds
    /// the acked variant's waits. `net` is charged [`Link::GpuGpu`] for
    /// every post actually sent.
    pub fn start(
        listener: TcpListener,
        rank: usize,
        world: usize,
        peer_addrs: &[String],
        timeout: Duration,
        net: Arc<NetSim>,
    ) -> Result<GossipFabric> {
        ensure!(
            peer_addrs.len() == world && rank < world,
            "gossip fabric: {} peer addresses for rank {rank} of world {world}",
            peer_addrs.len()
        );
        let slots: Arc<Vec<Slot>> = Arc::new((0..world).map(|_| Mutex::new(None)).collect());
        let stop = Arc::new(AtomicBool::new(false));

        listener.set_nonblocking(true).context("gossip listener nonblocking")?;
        {
            let slots = slots.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name(format!("gossip-accept-{rank}"))
                .spawn(move || accept_loop(listener, slots, stop))
                .context("spawning gossip accept thread")?;
        }

        let mut peers = Vec::with_capacity(world);
        for (r, addr) in peer_addrs.iter().enumerate() {
            if r == rank {
                peers.push(None);
                continue;
            }
            // Capacity 1: a fresh post supersedes a queued one anyway, so
            // the only queueing that matters is "the outbox thread is
            // mid-send" — in that case `try_send` fails and the post drops.
            let (tx, rx) = sync_channel::<Vec<u8>>(1);
            let addr_owned = addr.clone();
            std::thread::Builder::new()
                .name(format!("gossip-out-{rank}-to-{r}"))
                .spawn(move || outbox_loop(&addr_owned, rx))
                .context("spawning gossip outbox thread")?;
            peers.push(Some(PeerLink {
                addr: addr.clone(),
                outbox: tx,
                acked: Mutex::new(None),
            }));
        }

        Ok(GossipFabric { rank, world, seq: 0, slots, peers, timeout, net, stop })
    }

    /// Fire-and-forget: hand this replica to every peer's outbox (dropping
    /// the post wherever the outbox is busy), then average in whatever the
    /// peers most recently posted. Never blocks on any peer; returns the
    /// simulated seconds of the posts actually sent.
    pub fn post_and_average(&mut self, params: &mut [f32]) -> Result<f64> {
        self.seq += 1;
        let msg = encode_post(self.rank, self.seq, false, params);
        let mut sim = 0.0;
        for link in self.peers.iter().flatten() {
            // A full outbox means the peer is slow or unreachable: drop the
            // post (a fresher one is coming) rather than wait.
            if link.outbox.try_send(msg.clone()).is_ok() {
                sim += self.net.record(Link::GpuGpu, msg.len());
            }
        }
        self.average_into(params);
        Ok(sim)
    }

    /// Deterministic variant: post to every peer over a dedicated
    /// connection and wait for each receiver's ack (bounded by the fabric
    /// timeout) before averaging. Callers run this inside a token-ordered
    /// section, so "everything posted before my section" is exactly ranks
    /// `0..self_rank` of this round plus everyone's previous round — the
    /// same visibility the in-process shared-slot gossip has under the
    /// token, which is what the cross-deployment parity test asserts.
    pub fn post_acked_and_average(&mut self, params: &mut [f32]) -> Result<f64> {
        self.seq += 1;
        let msg = encode_post(self.rank, self.seq, true, params);
        let mut sim = 0.0;
        for link in self.peers.iter().flatten() {
            let mut conn = lock_unpoisoned(&link.acked);
            if conn.is_none() {
                *conn = Some(dial(&link.addr, self.timeout).with_context(|| {
                    format!("dialing gossip peer at {} for an acked post", link.addr)
                })?);
            }
            let t = conn.as_ref().expect("dialed above");
            let sent = t.send(msg.clone()).and_then(|()| t.recv()).and_then(|ack| {
                let r = WireReader::parse(&ack)?;
                ensure!(r.kind() == KIND_GOSSIP_ACK, "expected a gossip ack, got {:#x}", r.kind());
                let seq = r.u64(0)?;
                ensure!(
                    seq.first() == Some(&self.seq),
                    "gossip ack for seq {seq:?}, expected {}",
                    self.seq
                );
                Ok(())
            });
            if let Err(e) = sent {
                // The connection state is unknown after a failed exchange;
                // the next acked post re-dials.
                *conn = None;
                bail!("acked gossip post to {} failed: {e:#}", link.addr);
            }
            sim += self.net.record(Link::GpuGpu, msg.len());
        }
        self.average_into(params);
        Ok(sim)
    }

    /// Average `params` with every peer's latest post (skipping peers that
    /// have posted nothing, or a stale different-geometry post). Mirrors
    /// the in-process shared-slot average exactly — own replica first, then
    /// peers in rank order — so the two deployments sum in the same
    /// floating-point order.
    fn average_into(&self, params: &mut [f32]) {
        let mut acc = params.to_vec();
        let mut n = 1.0f32;
        for (i, slot) in self.slots.iter().enumerate() {
            if i == self.rank {
                continue;
            }
            let other = lock_unpoisoned(slot);
            if let Some((_, p)) = other.as_ref() {
                if p.len() == acc.len() {
                    for (a, o) in acc.iter_mut().zip(p.iter()) {
                        *a += o;
                    }
                    n += 1.0;
                }
            }
        }
        let inv = 1.0 / n;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        params.copy_from_slice(&acc);
    }

    /// Total ranks in the mesh.
    pub fn world(&self) -> usize {
        self.world
    }
}

impl Drop for GossipFabric {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

fn dial(addr: &str, timeout: Duration) -> Result<TcpTransport> {
    let sa: SocketAddr = addr.parse().with_context(|| format!("bad gossip address {addr}"))?;
    let stream = TcpStream::connect_timeout(&sa, timeout)?;
    let t = TcpTransport::new(stream);
    t.set_timeouts(Some(timeout))?;
    Ok(t)
}

/// Fire-and-forget sender to one peer: dial lazily, send what the bounded
/// channel delivers, drop the connection (and the post) on any error. Ends
/// when the fabric (the only `SyncSender`) is dropped.
fn outbox_loop(addr: &str, rx: std::sync::mpsc::Receiver<Vec<u8>>) {
    let mut conn: Option<TcpTransport> = None;
    for msg in rx {
        if conn.is_none() {
            conn = dial(addr, ASYNC_DIAL_TIMEOUT).ok();
        }
        if let Some(c) = &conn {
            if c.send(msg).is_err() {
                conn = None;
            }
        }
    }
}

/// Accept inbound gossip connections until the fabric stops; each gets its
/// own reader thread (posts are tiny and per-peer, so one thread per
/// inbound link stays small: at most `world - 1` async + `world - 1` acked
/// connections).
fn accept_loop(listener: TcpListener, slots: Arc<Vec<Slot>>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let slots = slots.clone();
                let stop = stop.clone();
                let _ = std::thread::Builder::new()
                    .name("gossip-reader".to_string())
                    .spawn(move || reader_loop(stream, &slots, &stop));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                wait_incoming(&listener, ACCEPT_POLL);
            }
            Err(_) => return,
        }
    }
}

/// Store each arriving post into its rank's slot (newest sequence wins) and
/// ack the ones that ask for it. Exits on any malformed frame or transport
/// error — the peer just re-dials.
fn reader_loop(stream: TcpStream, slots: &[Slot], stop: &AtomicBool) {
    let t = TcpTransport::new(stream);
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let Ok(msg) = t.recv() else { return };
        let Ok(r) = WireReader::parse(&msg) else { return };
        if r.kind() != KIND_GOSSIP {
            return;
        }
        let Ok(hdr) = r.u64(0) else { return };
        let Ok(params) = r.f32(1) else { return };
        if hdr.len() != 3 || hdr[0] as usize >= slots.len() {
            return;
        }
        let (peer_rank, seq, want_ack) = (hdr[0] as usize, hdr[1], hdr[2] == 1);
        {
            let mut slot = lock_unpoisoned(&slots[peer_rank]);
            let newer = match slot.as_ref() {
                Some((have, _)) => *have < seq,
                None => true,
            };
            if newer {
                *slot = Some((seq, params));
            }
        }
        if want_ack && t.send(encode_ack(seq)).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetModelConfig;

    fn mesh(world: usize) -> Vec<GossipFabric> {
        let listeners: Vec<TcpListener> =
            (0..world).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        let addrs: Vec<String> =
            listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        listeners
            .into_iter()
            .enumerate()
            .map(|(r, l)| {
                GossipFabric::start(
                    l,
                    r,
                    world,
                    &addrs,
                    Duration::from_secs(5),
                    net.clone(),
                )
                .unwrap()
            })
            .collect()
    }

    #[test]
    fn acked_posts_are_visible_immediately() {
        let mut fabrics = mesh(2);
        let mut f1 = fabrics.pop().unwrap();
        let mut f0 = fabrics.pop().unwrap();
        let mut p1 = vec![3.0f32, 5.0];
        f1.post_acked_and_average(&mut p1).unwrap();
        // Rank 1 averaged alone (rank 0 has posted nothing).
        assert_eq!(p1, vec![3.0, 5.0]);
        let mut p0 = vec![1.0f32, 1.0];
        f0.post_acked_and_average(&mut p0).unwrap();
        // Rank 0 sees rank 1's acked post: mean([1,1],[3,5]).
        assert_eq!(p0, vec![2.0, 3.0]);
    }

    #[test]
    fn async_posts_arrive_eventually_and_never_block() {
        let mut fabrics = mesh(2);
        let mut f1 = fabrics.pop().unwrap();
        let mut f0 = fabrics.pop().unwrap();
        let mut p1 = vec![4.0f32; 8];
        f1.post_and_average(&mut p1).unwrap();
        // Poll until rank 1's post lands at rank 0 (fire-and-forget has no
        // delivery guarantee at any instant, only eventually-on-a-live-link).
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let mut p0 = vec![2.0f32; 8];
            f0.post_and_average(&mut p0).unwrap();
            if p0 == vec![3.0f32; 8] {
                break;
            }
            assert_eq!(p0, vec![2.0f32; 8], "average must use whole replicas or nothing");
            assert!(std::time::Instant::now() < deadline, "post never arrived");
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    #[test]
    fn dead_peer_drops_posts_instead_of_blocking() {
        // Rank 1's address points at a bound-then-dropped listener: posts
        // can never be delivered. The async path must stay fast anyway.
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let dead = TcpListener::bind("127.0.0.1:0").unwrap();
        let addrs = vec![
            l0.local_addr().unwrap().to_string(),
            dead.local_addr().unwrap().to_string(),
        ];
        drop(dead);
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let mut f0 =
            GossipFabric::start(l0, 0, 2, &addrs, Duration::from_secs(5), net).unwrap();
        let mut p = vec![1.0f32; 4];
        let t0 = std::time::Instant::now();
        for _ in 0..50 {
            f0.post_and_average(&mut p).unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "fire-and-forget posts blocked on a dead peer: {:?}",
            t0.elapsed()
        );
        assert_eq!(p, vec![1.0f32; 4], "no peer ever posted, params must be unchanged");
    }

    #[test]
    fn stale_or_mismatched_posts_are_ignored() {
        let mut fabrics = mesh(2);
        let mut f1 = fabrics.pop().unwrap();
        let mut f0 = fabrics.pop().unwrap();
        let mut long = vec![9.0f32; 4];
        f1.post_acked_and_average(&mut long).unwrap();
        // Rank 0 averages a DIFFERENT length: rank 1's post must be skipped.
        let mut p0 = vec![1.0f32, 1.0];
        f0.post_acked_and_average(&mut p0).unwrap();
        assert_eq!(p0, vec![1.0, 1.0]);
    }
}
