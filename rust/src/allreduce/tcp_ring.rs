//! Ring AllReduce over real TCP sockets — the multi-process deployment of
//! §4.2.3's "Optimized communication among NN workers".
//!
//! Each NN-worker **process** holds one [`TcpRingMember`]: a socket to its
//! successor rank and one from its predecessor, wired up by a tiny
//! rendezvous ([`RingRendezvous`]): rank 0 listens, every other rank dials
//! it, presents `(rank, world, config fingerprint)` — the same policy as
//! the PS INFO handshake — and receives the full ring address table back.
//! A world-size or fingerprint mismatch is rejected at connect time (both
//! sides fail loudly) instead of desynchronizing mid-step.
//!
//! The AllReduce itself runs the *identical* two-phase schedule as the
//! in-process [`RingMember`](super::ring::RingMember) — same
//! [`chunk_range`] splits, same `own += incoming` accumulation — so with
//! compression off the TCP ring is bit-for-bit equal to the threaded ring
//! (and to [`reference_sum`](super::ring::reference_sum)). Chunks travel as
//! [`crate::comm::wire`] frames — one contiguous f32 (or fp16 + scale,
//! `compress: true`) section each, one length-prefixed write per bucket —
//! streamed as bounded `SEG_ELEMS` segments with send/receive
//! interleaved, so arbitrarily large gradients can never wedge two peers
//! in simultaneous blocking writes; per-layer gradients flatten into the
//! contiguous buffer via [`FlatBuckets`](super::bucket::FlatBuckets)
//! ([`TcpRingMember::all_reduce_mean_tensors`]).
//!
//! Every frame carries a sequence number, and receives are bounded by the
//! configured timeout, so a killed peer or a schedule desync surfaces as a
//! clean error within the timeout — never a hang. [`NetSim`] is charged the
//! GpuGpu bytes *actually sent* (frame length, compressed or not).

use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::comm::compress::CompressedValues;
use crate::comm::netsim::{Link, NetSim};
use crate::comm::transport::{TcpTransport, Transport};
use crate::comm::wire::{WireReader, WireWriter};
use crate::config::RingConfig;
use crate::recovery::{dial_retry, remaining};
use crate::tensor::Tensor;

use super::bucket::FlatBuckets;
use super::gossip::{wait_incoming, GossipFabric};
use super::ring::chunk_range;

/// Wire message kinds of the NN-worker ring (disjoint from the PS service's
/// 0x5xxx range).
pub const KIND_RDZV_HELLO: u32 = 0x6001;
/// Rendezvous acceptance: carries the full ring address table.
pub const KIND_RDZV_WELCOME: u32 = 0x6002;
/// Rendezvous rejection (world/fingerprint mismatch, duplicate rank).
pub const KIND_RDZV_REJECT: u32 = 0x6003;
/// Ring-neighbour introduction after the rendezvous.
pub const KIND_RING_HELLO: u32 = 0x6004;
/// One AllReduce chunk segment (seq-numbered).
pub const KIND_RING_DATA: u32 = 0x6005;
/// The deterministic-ordering token (zero-length payload).
pub const KIND_RING_TOKEN: u32 = 0x6006;

/// Largest f32 payload per DATA frame (16 KiB). Every rank alternates
/// "send one segment, receive one segment", and a pending 16 KiB write
/// always fits the peer's socket buffers — so two peers blocking in
/// `write_all` on each other (the classic big-tensor TCP deadlock, which
/// the unbounded in-process channels can never hit) is impossible no
/// matter how large the gradient is.
const SEG_ELEMS: usize = 4096;

fn encode_hello(kind: u32, rank: usize, world: usize, fingerprint: u64, addr: &str) -> Vec<u8> {
    let mut w = WireWriter::new(kind);
    w.put_u64(&[rank as u64, world as u64, fingerprint]);
    w.put_u8(addr.as_bytes());
    w.finish()
}

/// Split a rendezvous table entry into its `(ring, gossip)` addresses.
/// Entries travel as `"ring_addr|gossip_addr"` since the gossip fabric
/// rides the same rendezvous (see [`super::gossip`]).
fn split_entry(entry: &str) -> Result<(&str, &str)> {
    entry
        .split_once('|')
        .with_context(|| format!("malformed rendezvous entry {entry:?} (expected ring|gossip)"))
}

/// Returns `(rank, world, fingerprint, ring address)`.
fn decode_hello(msg: &[u8], want_kind: u32) -> Result<(usize, usize, u64, String)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == want_kind, "expected hello kind {want_kind:#x}, got {:#x}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 3, "malformed ring hello ({} fields)", xs.len());
    let addr = String::from_utf8(r.u8(1)?.to_vec()).context("ring hello address")?;
    Ok((xs[0] as usize, xs[1] as usize, xs[2], addr))
}

fn encode_reject(reason: &str) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_RDZV_REJECT);
    w.put_u8(reason.as_bytes());
    w.finish()
}

fn encode_welcome(table: &[String]) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_RDZV_WELCOME);
    w.put_u8(table.join(",").as_bytes());
    w.finish()
}

/// Prepare the accepted/dialed socket for the rendezvous phase.
fn configure(stream: &TcpStream, deadline: Instant) -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(remaining(deadline)))?;
    stream.set_write_timeout(Some(remaining(deadline)))?;
    Ok(())
}

/// Accept one connection before `deadline` from a listener (made
/// non-blocking so the wait is bounded).
fn accept_deadline(listener: &TcpListener, deadline: Instant, what: &str) -> Result<TcpStream> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((stream, _)) => return Ok(stream),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!("timed out waiting for {what}");
                }
                // poll(2)-backed wait: wakes the moment a connection lands
                // instead of on a sleep grid.
                wait_incoming(listener, remaining(deadline).min(Duration::from_millis(50)));
            }
            Err(e) => return Err(e).with_context(|| format!("accepting {what}")),
        }
    }
}

/// A bound-but-not-yet-connected ring endpoint. Binding is split from
/// connecting so rank 0 can print its (possibly ephemeral) rendezvous
/// address for orchestrators *before* blocking on peers.
pub struct RingRendezvous {
    cfg: RingConfig,
    ring_listener: TcpListener,
    ring_addr: String,
    /// FullAsync gossip inbound listener, bound *before* the rendezvous so
    /// its address can ride the table (`"ring|gossip"` entries).
    gossip_listener: TcpListener,
    gossip_addr: String,
    /// Rank 0 only.
    rdzv_listener: Option<TcpListener>,
}

impl RingRendezvous {
    /// Bind this rank's ring-inbound and gossip-inbound listeners
    /// (ephemeral ports on `cfg.bind_host`) and, on rank 0, the rendezvous
    /// listener.
    pub fn bind(cfg: &RingConfig) -> Result<RingRendezvous> {
        cfg.validate()?;
        let ring_listener = TcpListener::bind((cfg.bind_host.as_str(), 0))
            .with_context(|| format!("binding ring listener on {}", cfg.bind_host))?;
        let ring_addr = ring_listener.local_addr()?.to_string();
        let gossip_listener = TcpListener::bind((cfg.bind_host.as_str(), 0))
            .with_context(|| format!("binding gossip listener on {}", cfg.bind_host))?;
        let gossip_addr = gossip_listener.local_addr()?.to_string();
        let rdzv_listener = if cfg.rank == 0 && cfg.world > 1 {
            Some(
                TcpListener::bind(&cfg.rendezvous)
                    .with_context(|| format!("binding rendezvous on {}", cfg.rendezvous))?,
            )
        } else {
            None
        };
        Ok(RingRendezvous {
            cfg: cfg.clone(),
            ring_listener,
            ring_addr,
            gossip_listener,
            gossip_addr,
            rdzv_listener,
        })
    }

    /// The rendezvous address peers must dial (rank 0 only; resolves an
    /// ephemeral port 0 to the concrete one).
    pub fn rendezvous_addr(&self) -> Result<SocketAddr> {
        match &self.rdzv_listener {
            Some(l) => Ok(l.local_addr()?),
            None => bail!("only rank 0 of a world > 1 ring owns the rendezvous listener"),
        }
    }

    /// Run the rendezvous + ring handshake and return the connected member.
    /// `fingerprint` must summarize every config knob that changes the run's
    /// numerics; peers whose fingerprint (or world size) differs are
    /// rejected here, on both sides of the connection.
    pub fn connect(mut self, fingerprint: u64, net: Arc<NetSim>) -> Result<TcpRingMember> {
        let cfg = self.cfg.clone();
        if cfg.world == 1 {
            return Ok(TcpRingMember {
                rank: 0,
                world: 1,
                send: None,
                recv: None,
                net,
                compress: cfg.compress,
                seq_out: 0,
                seq_in: 0,
                gossip: None,
            });
        }
        let deadline = Instant::now() + Duration::from_millis(cfg.timeout_ms);
        // Every table entry pairs the ring and gossip inbound addresses.
        let my_entry = format!("{}|{}", self.ring_addr, self.gossip_addr);
        let table = match self.rdzv_listener.take() {
            Some(listener) => collect_peers(listener, &cfg, fingerprint, &my_entry, deadline),
            None => join_rendezvous(&cfg, fingerprint, &my_entry, deadline),
        }?;

        // Dial the successor first (its listener is already bound), then
        // accept the predecessor; both sides validate a RING_HELLO so a
        // mis-wired table cannot silently cross-connect rings.
        let succ = (cfg.rank + 1) % cfg.world;
        let pred = (cfg.rank + cfg.world - 1) % cfg.world;
        let send_stream = dial_retry(split_entry(&table[succ])?.0, deadline, "ring successor")?;
        configure(&send_stream, deadline)?;
        let send = TcpTransport::new(send_stream);
        send.send(encode_hello(KIND_RING_HELLO, cfg.rank, cfg.world, fingerprint, &my_entry))
            .context("sending ring hello to successor")?;

        let recv_stream = accept_deadline(&self.ring_listener, deadline, "ring predecessor")?;
        configure(&recv_stream, deadline)?;
        let recv = TcpTransport::new(recv_stream);
        let hello = recv.recv().context("waiting for ring predecessor hello")?;
        let (p_rank, p_world, p_fp, _) = decode_hello(&hello, KIND_RING_HELLO)?;
        ensure!(
            p_rank == pred && p_world == cfg.world && p_fp == fingerprint,
            "ring handshake mismatch: predecessor claims rank {p_rank}/{p_world} \
             fingerprint {p_fp:#x}, expected rank {pred}/{} fingerprint {fingerprint:#x}",
            cfg.world
        );

        // Switch both links to the steady-state per-receive timeout so a
        // peer dying mid-run surfaces as an error within `timeout_ms`.
        let op = Duration::from_millis(cfg.timeout_ms);
        send.set_timeouts(Some(op))?;
        recv.set_timeouts(Some(op))?;

        // Stand up the FullAsync gossip mesh from the table's gossip
        // halves; its connections form lazily on first post.
        let gossip_addrs = table
            .iter()
            .map(|e| Ok(split_entry(e)?.1.to_string()))
            .collect::<Result<Vec<String>>>()?;
        let gossip = GossipFabric::start(
            self.gossip_listener,
            cfg.rank,
            cfg.world,
            &gossip_addrs,
            op,
            net.clone(),
        )?;

        Ok(TcpRingMember {
            rank: cfg.rank,
            world: cfg.world,
            send: Some(send),
            recv: Some(recv),
            net,
            compress: cfg.compress,
            seq_out: 0,
            seq_in: 0,
            gossip: Some(gossip),
        })
    }
}

/// Rank 0: collect one HELLO per peer rank, reject mismatches (telling the
/// peer why), then broadcast the ring address table.
fn collect_peers(
    listener: TcpListener,
    cfg: &RingConfig,
    fingerprint: u64,
    my_ring_addr: &str,
    deadline: Instant,
) -> Result<Vec<String>> {
    listener.set_nonblocking(true)?;
    // Slot r-1 holds peer rank r's (connection, ring address).
    let mut peers: Vec<Option<(TcpTransport, String)>> = Vec::new();
    peers.resize_with(cfg.world - 1, || None);
    let mut got = 0usize;
    while got < cfg.world - 1 {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!(
                        "rendezvous timed out: {got} of {} peers joined within {}ms",
                        cfg.world - 1,
                        cfg.timeout_ms
                    );
                }
                wait_incoming(&listener, remaining(deadline).min(Duration::from_millis(50)));
                continue;
            }
            Err(e) => return Err(e).context("rendezvous accept"),
        };
        configure(&stream, deadline)?;
        // Peers send their HELLO immediately after dialing, so cap this
        // connection's read wait well below the full deadline: a stray
        // dialer that connects and goes silent (a port probe holding the
        // socket open) then costs at most the grace period instead of
        // starving the single-threaded rendezvous for its whole budget.
        let grace = remaining(deadline).min(Duration::from_secs(2));
        stream.set_read_timeout(Some(grace)).ok();
        let t = TcpTransport::new(stream);
        let hello = match t.recv().and_then(|msg| decode_hello(&msg, KIND_RDZV_HELLO)) {
            Ok(h) => h,
            // A stray dialer (port scan, orchestrator probe) must not kill
            // the rendezvous; drop the connection and keep listening.
            Err(_) => continue,
        };
        let (rank, world, fp, addr) = hello;
        let reject = |t: &TcpTransport, reason: String| -> Result<Vec<String>> {
            let _ = t.send(encode_reject(&reason));
            bail!("rendezvous rejected a worker: {reason}");
        };
        if world != cfg.world {
            return reject(
                &t,
                format!("world size mismatch: worker says {world}, rank 0 expects {}", cfg.world),
            );
        }
        if fp != fingerprint {
            return reject(
                &t,
                format!(
                    "config fingerprint mismatch: worker {fp:#x} != rank 0 {fingerprint:#x} — \
                     start every train-worker with the same flags"
                ),
            );
        }
        if rank == 0 || rank >= cfg.world {
            return reject(&t, format!("rank {rank} out of range for world {}", cfg.world));
        }
        if peers[rank - 1].is_some() {
            return reject(&t, format!("duplicate rank {rank} joined the rendezvous"));
        }
        peers[rank - 1] = Some((t, addr));
        got += 1;
    }
    let mut table = Vec::with_capacity(cfg.world);
    table.push(my_ring_addr.to_string());
    for slot in &peers {
        table.push(slot.as_ref().expect("all peers collected").1.clone());
    }
    let welcome = encode_welcome(&table);
    for slot in &peers {
        slot.as_ref()
            .expect("all peers collected")
            .0
            .send(welcome.clone())
            .context("sending rendezvous welcome")?;
    }
    Ok(table)
}

/// Ranks 1..world: dial rank 0, present the handshake, receive the table.
fn join_rendezvous(
    cfg: &RingConfig,
    fingerprint: u64,
    my_ring_addr: &str,
    deadline: Instant,
) -> Result<Vec<String>> {
    let stream = dial_retry(&cfg.rendezvous, deadline, "rendezvous (rank 0)")?;
    configure(&stream, deadline)?;
    let t = TcpTransport::new(stream);
    t.send(encode_hello(KIND_RDZV_HELLO, cfg.rank, cfg.world, fingerprint, my_ring_addr))
        .context("sending rendezvous hello")?;
    let resp = t.recv().context("waiting for rendezvous welcome")?;
    let r = WireReader::parse(&resp)?;
    match r.kind() {
        KIND_RDZV_WELCOME => {
            let table: Vec<String> = String::from_utf8(r.u8(0)?.to_vec())
                .context("rendezvous table")?
                .split(',')
                .map(|s| s.to_string())
                .collect();
            ensure!(
                table.len() == cfg.world,
                "rendezvous table has {} entries for world {}",
                table.len(),
                cfg.world
            );
            ensure!(
                table[cfg.rank] == my_ring_addr,
                "rendezvous table slot {} is {}, not this worker's {}",
                cfg.rank,
                table[cfg.rank],
                my_ring_addr
            );
            Ok(table)
        }
        KIND_RDZV_REJECT => {
            let reason = String::from_utf8_lossy(r.u8(0)?).to_string();
            bail!("rendezvous rejected this worker: {reason}")
        }
        k => bail!("unexpected rendezvous response kind {k:#x}"),
    }
}

/// One process's member of a TCP ring AllReduce group.
pub struct TcpRingMember {
    rank: usize,
    world: usize,
    /// To the successor rank (`None` iff world == 1).
    send: Option<TcpTransport>,
    /// From the predecessor rank (`None` iff world == 1).
    recv: Option<TcpTransport>,
    net: Arc<NetSim>,
    compress: bool,
    /// Frames sent/received, matched against the peer's counters on every
    /// frame so a schedule desync errors instead of corrupting gradients.
    seq_out: u64,
    seq_in: u64,
    /// FullAsync best-effort replica gossip mesh (`None` iff world == 1).
    gossip: Option<GossipFabric>,
}

impl TcpRingMember {
    /// This process's rank in `0..world`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Total ranks in the ring.
    pub fn world(&self) -> usize {
        self.world
    }

    fn send_link(&self) -> &TcpTransport {
        self.send.as_ref().expect("ring links exist for world > 1")
    }

    fn recv_link(&self) -> &TcpTransport {
        self.recv.as_ref().expect("ring links exist for world > 1")
    }

    /// Encode + send one chunk as a single wire frame; charges [`NetSim`]
    /// the bytes actually written. Returns the simulated transfer seconds.
    fn send_chunk(&mut self, chunk: &[f32]) -> Result<f64> {
        let mut w = WireWriter::new(KIND_RING_DATA);
        w.put_u64(&[self.seq_out]);
        if self.compress && !chunk.is_empty() {
            let c = CompressedValues::compress(chunk, chunk.len());
            w.put_f16(&c.vals);
            w.put_f32(&c.scales);
        } else {
            w.put_f32(chunk);
        }
        let msg = w.finish();
        let sim = self.net.record(Link::GpuGpu, msg.len());
        self.seq_out += 1;
        self.send_link().send(msg).context("ring send to successor")?;
        Ok(sim)
    }

    /// Receive one chunk (self-describing raw-f32 or fp16+scale payload) and
    /// validate its sequence number and length.
    fn recv_chunk(&mut self, want_len: usize) -> Result<Vec<f32>> {
        let msg = self.recv_link().recv().context(
            "ring recv from predecessor (peer dead, or slower than the ring timeout)",
        )?;
        let r = WireReader::parse(&msg)?;
        ensure!(
            r.kind() == KIND_RING_DATA,
            "ring desynchronized: expected a DATA frame, got kind {:#x}",
            r.kind()
        );
        let seq = r.u64(0)?;
        ensure!(
            seq.len() == 1 && seq[0] == self.seq_in,
            "ring desynchronized: frame seq {seq:?}, expected {}",
            self.seq_in
        );
        self.seq_in += 1;
        let vals: Vec<f32> = match r.f32(1) {
            Ok(raw) => raw,
            Err(_) => {
                let vals = r.f16(1)?;
                let scales = r.f32(2)?;
                let dim = if vals.is_empty() {
                    1
                } else {
                    ensure!(!scales.is_empty(), "corrupt compressed ring frame: no scales");
                    vals.len() / scales.len()
                };
                ensure!(
                    scales.len() * dim == vals.len(),
                    "corrupt compressed ring frame: {} values / {} scales",
                    vals.len(),
                    scales.len()
                );
                CompressedValues { vals, scales, dim }.decompress()
            }
        };
        ensure!(
            vals.len() == want_len,
            "ring desynchronized: chunk of {} elements, expected {want_len}",
            vals.len()
        );
        Ok(vals)
    }

    /// One ring step: stream chunk `send_c` to the successor while
    /// receiving chunk `recv_c` from the predecessor, segment by segment
    /// (both sides compute the identical segmentation from the chunk
    /// lengths, so the frames pair up FIFO per link). `reduce` accumulates
    /// the incoming data (`+=`, reduce-scatter); otherwise it overwrites
    /// (all-gather).
    fn ring_step(
        &mut self,
        buf: &mut [f32],
        send_c: std::ops::Range<usize>,
        recv_c: std::ops::Range<usize>,
        reduce: bool,
    ) -> Result<f64> {
        let mut sim = 0.0;
        let send_len = send_c.len();
        let recv_len = recv_c.len();
        let segs = |len: usize| (len + SEG_ELEMS - 1) / SEG_ELEMS;
        for i in 0..segs(send_len).max(segs(recv_len)) {
            if i * SEG_ELEMS < send_len {
                let lo = send_c.start + i * SEG_ELEMS;
                let hi = (lo + SEG_ELEMS).min(send_c.end);
                sim += self.send_chunk(&buf[lo..hi])?;
            }
            if i * SEG_ELEMS < recv_len {
                let lo = recv_c.start + i * SEG_ELEMS;
                let hi = (lo + SEG_ELEMS).min(recv_c.end);
                let incoming = self.recv_chunk(hi - lo)?;
                if reduce {
                    for (a, &b) in buf[lo..hi].iter_mut().zip(&incoming) {
                        *a += b;
                    }
                } else {
                    buf[lo..hi].copy_from_slice(&incoming);
                }
            }
        }
        Ok(sim)
    }

    /// In-place AllReduce (sum) across all ranks' `buf` (equal lengths).
    /// Identical schedule and accumulation order as the in-process
    /// [`RingMember`](super::ring::RingMember). Returns simulated seconds.
    pub fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<f64> {
        let k = self.world;
        if k == 1 {
            return Ok(0.0);
        }
        let n = buf.len();
        let mut sim = 0.0;
        // Phase 1: reduce-scatter.
        for s in 0..k - 1 {
            let send_c = (self.rank + k - s) % k;
            let recv_c = (self.rank + k - s - 1) % k;
            sim += self.ring_step(
                buf,
                chunk_range(n, k, send_c),
                chunk_range(n, k, recv_c),
                true,
            )?;
        }
        // Phase 2: all-gather.
        for s in 0..k - 1 {
            let send_c = (self.rank + 1 + k - s) % k;
            let recv_c = (self.rank + k - s) % k;
            sim += self.ring_step(
                buf,
                chunk_range(n, k, send_c),
                chunk_range(n, k, recv_c),
                false,
            )?;
        }
        Ok(sim)
    }

    /// In-place AllReduce (mean). Returns simulated seconds.
    pub fn all_reduce_mean(&mut self, buf: &mut [f32]) -> Result<f64> {
        let sim = self.all_reduce_sum(buf)?;
        let inv = 1.0 / self.world as f32;
        for x in buf.iter_mut() {
            *x *= inv;
        }
        Ok(sim)
    }

    /// AllReduce-mean a list of per-layer tensors by flattening them into
    /// one contiguous buffer ([`FlatBuckets`]) first — Bagua's bucketed
    /// send path: large fused chunks on the wire instead of one message per
    /// small tensor.
    pub fn all_reduce_mean_tensors(
        &mut self,
        tensors: &mut [Tensor],
        bucket_elems: usize,
    ) -> Result<f64> {
        let mut fb = FlatBuckets::flatten(tensors, bucket_elems);
        let sim = self.all_reduce_mean(fb.flat_mut())?;
        fb.unflatten_into(tensors);
        Ok(sim)
    }

    /// Pass the deterministic-ordering token to the successor rank.
    pub fn send_token(&mut self) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let mut w = WireWriter::new(KIND_RING_TOKEN);
        w.put_u64(&[self.seq_out]);
        self.seq_out += 1;
        self.send_link().send(w.finish()).context("ring token send")
    }

    /// Receive the deterministic-ordering token from the predecessor rank.
    pub fn recv_token(&mut self) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let msg = self.recv_link().recv().context("ring token recv")?;
        let r = WireReader::parse(&msg)?;
        ensure!(
            r.kind() == KIND_RING_TOKEN,
            "ring desynchronized: expected an ordering token, got kind {:#x}",
            r.kind()
        );
        let seq = r.u64(0)?;
        ensure!(
            seq.len() == 1 && seq[0] == self.seq_in,
            "ring desynchronized: token seq {seq:?}, expected {}",
            self.seq_in
        );
        self.seq_in += 1;
        Ok(())
    }

    /// Best-effort FullAsync replica averaging: post this rank's `params`
    /// to every peer without waiting (posts to slow or dead peers are
    /// dropped) and average in whatever the peers most recently posted.
    /// Never blocks on any peer — see [`GossipFabric::post_and_average`].
    pub fn gossip_average(&mut self, params: &mut [f32]) -> Result<f64> {
        match &mut self.gossip {
            Some(g) => g.post_and_average(params),
            None => Ok(0.0),
        }
    }

    /// Deterministic gossip: post with per-peer acknowledgement before
    /// averaging, so replica visibility is a pure function of the caller's
    /// position in the token order — see
    /// [`GossipFabric::post_acked_and_average`].
    pub fn gossip_average_acked(&mut self, params: &mut [f32]) -> Result<f64> {
        match &mut self.gossip {
            Some(g) => g.post_acked_and_average(params),
            None => Ok(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allreduce::ring::{reference_mean, RingGroup};
    use crate::config::NetModelConfig;
    use crate::util::Rng;

    fn cfg(rank: usize, world: usize, rendezvous: &str, compress: bool) -> RingConfig {
        RingConfig {
            rendezvous: rendezvous.to_string(),
            rank,
            world,
            bind_host: "127.0.0.1".to_string(),
            timeout_ms: 10_000,
            compress,
        }
    }

    /// Wire up a full ring on loopback, every member charging `net`;
    /// returns one member per rank.
    fn connect_ring_on(
        world: usize,
        compress: bool,
        fingerprint: u64,
        net: Arc<NetSim>,
    ) -> Vec<TcpRingMember> {
        let rz0 = RingRendezvous::bind(&cfg(0, world, "127.0.0.1:0", compress)).unwrap();
        let addr = if world > 1 {
            rz0.rendezvous_addr().unwrap().to_string()
        } else {
            "127.0.0.1:0".to_string()
        };
        let mut handles = Vec::new();
        {
            let net = net.clone();
            handles.push(std::thread::spawn(move || rz0.connect(fingerprint, net).unwrap()));
        }
        for r in 1..world {
            let c = cfg(r, world, &addr, compress);
            let net = net.clone();
            handles.push(std::thread::spawn(move || {
                RingRendezvous::bind(&c).unwrap().connect(fingerprint, net).unwrap()
            }));
        }
        let mut members: Vec<TcpRingMember> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        members.sort_by_key(|m| m.rank());
        members
    }

    /// [`connect_ring_on`] with a throwaway cost model.
    fn connect_ring(world: usize, compress: bool, fingerprint: u64) -> Vec<TcpRingMember> {
        connect_ring_on(
            world,
            compress,
            fingerprint,
            Arc::new(NetSim::new(NetModelConfig::disabled())),
        )
    }

    fn threaded_ring_outputs(inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let k = inputs.len();
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let members = RingGroup::new(k, net);
        let handles: Vec<_> = members
            .into_iter()
            .zip(inputs.to_vec())
            .map(|(m, mut buf)| {
                std::thread::spawn(move || {
                    m.all_reduce_mean(&mut buf);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn tcp_ring_outputs(members: Vec<TcpRingMember>, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let handles: Vec<_> = members
            .into_iter()
            .zip(inputs.to_vec())
            .map(|(mut m, mut buf)| {
                std::thread::spawn(move || {
                    m.all_reduce_mean(&mut buf).unwrap();
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn tcp_ring_is_bit_identical_to_threaded_ring() {
        for k in [1usize, 2, 3, 4] {
            for n in [1usize, 7, 64, 255] {
                let mut rng = Rng::new((k * 100 + n) as u64);
                let inputs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(n)).collect();
                let threaded = threaded_ring_outputs(&inputs);
                let members = connect_ring(k, false, 0xFEED);
                let tcp = tcp_ring_outputs(members, &inputs);
                let want = reference_mean(&inputs);
                for (rank, (a, b)) in threaded.iter().zip(&tcp).enumerate() {
                    assert_eq!(a, b, "k={k} n={n} rank={rank}: threaded != tcp");
                    assert_eq!(b, &want, "k={k} n={n} rank={rank}: tcp != reference");
                }
            }
        }
    }

    #[test]
    fn large_buffers_stream_without_deadlock() {
        // 600 KB per ring direction — far beyond loopback socket buffers.
        // Whole-chunk blocking writes would wedge both peers; the segmented
        // interleave must complete (and still be exact for integer data).
        let members = connect_ring(2, false, 21);
        let handles: Vec<_> = members
            .into_iter()
            .map(|mut m| {
                std::thread::spawn(move || {
                    let mut buf = vec![(m.rank() + 1) as f32; 300_000];
                    m.all_reduce_sum(&mut buf).unwrap();
                    assert!(buf.iter().all(|&x| x == 3.0), "bad sum");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn repeated_reductions_reuse_the_ring() {
        let members = connect_ring(3, false, 1);
        let handles: Vec<_> = members
            .into_iter()
            .map(|mut m| {
                std::thread::spawn(move || {
                    for round in 0..5u32 {
                        let mut buf = vec![(m.rank() + 1) as f32 + round as f32; 10];
                        m.all_reduce_sum(&mut buf).unwrap();
                        let want = (1 + 2 + 3) as f32 + 3.0 * round as f32;
                        assert!(buf.iter().all(|&x| x == want), "round {round}: {buf:?}");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn tokens_serialize_ranks_over_tcp() {
        let members = connect_ring(3, false, 2);
        let log = Arc::new(std::sync::Mutex::new(Vec::new()));
        let handles: Vec<_> = members
            .into_iter()
            .map(|mut m| {
                let log = log.clone();
                std::thread::spawn(move || {
                    for _round in 0..3 {
                        if m.rank() > 0 {
                            m.recv_token().unwrap();
                        }
                        log.lock().unwrap().push(m.rank());
                        m.send_token().unwrap();
                        if m.rank() == 0 {
                            m.recv_token().unwrap();
                        }
                        // Tokens and data interleave cleanly.
                        let mut buf = vec![1.0f32; 4];
                        m.all_reduce_sum(&mut buf).unwrap();
                        assert!(buf.iter().all(|&x| x == 3.0));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn tensor_allreduce_flattens_through_flatbuckets() {
        let members = connect_ring(2, false, 11);
        let shapes = vec![vec![3usize, 2], vec![5usize]];
        let handles: Vec<_> = members
            .into_iter()
            .map(|mut m| {
                let shapes = shapes.clone();
                std::thread::spawn(move || {
                    let v = (m.rank() + 1) as f32;
                    let mut ts: Vec<Tensor> = shapes
                        .iter()
                        .map(|s| Tensor::from_vec(s, vec![v; s.iter().product()]))
                        .collect();
                    m.all_reduce_mean_tensors(&mut ts, 4).unwrap();
                    ts
                })
            })
            .collect();
        for h in handles {
            let ts = h.join().unwrap();
            for t in &ts {
                // mean(1, 2) = 1.5, exactly, in every original shape.
                assert!(t.data().iter().all(|&x| x == 1.5), "{:?}", t.data());
            }
        }
    }

    #[test]
    fn fingerprint_mismatch_rejected_on_both_sides() {
        let rz0 = RingRendezvous::bind(&cfg(0, 2, "127.0.0.1:0", false)).unwrap();
        let addr = rz0.rendezvous_addr().unwrap().to_string();
        let net0 = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let h0 = std::thread::spawn(move || rz0.connect(0xAAAA, net0));
        let c1 = cfg(1, 2, &addr, false);
        let h1 = std::thread::spawn(move || {
            let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
            RingRendezvous::bind(&c1).unwrap().connect(0xBBBB, net)
        });
        let e0 = h0.join().unwrap().err().expect("rank 0 must reject");
        let e1 = h1.join().unwrap().err().expect("rank 1 must be rejected");
        assert!(format!("{e0:#}").contains("fingerprint"), "rank 0 error: {e0:#}");
        assert!(format!("{e1:#}").contains("fingerprint"), "rank 1 error: {e1:#}");
    }

    #[test]
    fn world_size_mismatch_rejected_at_connect() {
        let rz0 = RingRendezvous::bind(&cfg(0, 2, "127.0.0.1:0", false)).unwrap();
        let addr = rz0.rendezvous_addr().unwrap().to_string();
        let net0 = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let h0 = std::thread::spawn(move || rz0.connect(7, net0));
        let c1 = cfg(1, 3, &addr, false); // claims a 3-rank world
        let h1 = std::thread::spawn(move || {
            let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
            RingRendezvous::bind(&c1).unwrap().connect(7, net)
        });
        let e0 = h0.join().unwrap().err().expect("rank 0 must reject");
        let e1 = h1.join().unwrap().err().expect("peer must be rejected");
        assert!(format!("{e0:#}").contains("world size mismatch"), "rank 0 error: {e0:#}");
        assert!(format!("{e1:#}").contains("world size mismatch"), "rank 1 error: {e1:#}");
    }

    #[test]
    fn silent_stray_connection_does_not_starve_rendezvous() {
        // A probe that connects to the rendezvous and says nothing (an
        // orchestrator's wait-for-port pattern) costs at most the hello
        // grace period — the real peer still joins and the ring forms.
        let rz0 = RingRendezvous::bind(&cfg(0, 2, "127.0.0.1:0", false)).unwrap();
        let addr = rz0.rendezvous_addr().unwrap().to_string();
        let stray = std::net::TcpStream::connect(addr.as_str()).unwrap();
        let net0 = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let h0 = std::thread::spawn(move || rz0.connect(13, net0).unwrap());
        let c1 = cfg(1, 2, &addr, false);
        let h1 = std::thread::spawn(move || {
            let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
            RingRendezvous::bind(&c1).unwrap().connect(13, net).unwrap()
        });
        let m0 = h0.join().unwrap();
        let m1 = h1.join().unwrap();
        let handles = [
            std::thread::spawn(move || {
                let mut m0 = m0;
                let mut buf = vec![1.0f32; 4];
                m0.all_reduce_sum(&mut buf).unwrap();
                buf
            }),
            std::thread::spawn(move || {
                let mut m1 = m1;
                let mut buf = vec![2.0f32; 4];
                m1.all_reduce_sum(&mut buf).unwrap();
                buf
            }),
        ];
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![3.0; 4]);
        }
        drop(stray);
    }

    #[test]
    fn missing_peer_times_out_instead_of_hanging() {
        let mut c = cfg(0, 2, "127.0.0.1:0", false);
        c.timeout_ms = 300;
        let rz = RingRendezvous::bind(&c).unwrap();
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let t0 = Instant::now();
        let err = rz.connect(1, net).err().expect("must time out");
        assert!(format!("{err:#}").contains("timed out"), "error: {err:#}");
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn dead_peer_surfaces_as_error_not_hang() {
        let members = connect_ring(2, false, 3);
        let mut it = members.into_iter();
        let mut m0 = it.next().unwrap();
        let m1 = it.next().unwrap();
        drop(m1); // rank 1 "dies": its sockets close
        let mut buf = vec![1.0f32; 8];
        let err = m0.all_reduce_sum(&mut buf).err().expect("must error");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("ring") || msg.contains("peer"),
            "unexpected error: {msg}"
        );
    }

    #[test]
    fn gpu_bytes_follow_the_bandwidth_optimal_schedule() {
        // Each rank sends 2(k-1)/k * n floats (+ a fixed frame header per
        // chunk); NetSim's GpuGpu accounting must reflect the bytes
        // actually sent, and nothing may leak onto the CPU links.
        for k in [2usize, 4] {
            let n = 4096usize;
            let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
            let members = connect_ring_on(k, false, 9, net.clone());
            let workers: Vec<_> = members
                .into_iter()
                .map(|mut m| {
                    std::thread::spawn(move || {
                        let mut buf = vec![1.0f32; 4096];
                        m.all_reduce_sum(&mut buf).unwrap();
                    })
                })
                .collect();
            for w in workers {
                w.join().unwrap();
            }
            let payload = (k * (2 * (k - 1) * n / k) * 4) as u64;
            let frames = (k * 2 * (k - 1)) as u64;
            let got = net.link_bytes(Link::GpuGpu);
            assert!(
                got >= payload && got <= payload + frames * 96 + (k * k * 4) as u64,
                "k={k}: gpu bytes {got} vs payload {payload} (+{frames} frame headers)"
            );
            assert_eq!(net.link_bytes(Link::CpuGpu), 0, "dense swap leaked onto CpuGpu");
            assert_eq!(net.link_bytes(Link::CpuCpu), 0, "dense swap leaked onto CpuCpu");
            // Simulated ns are exactly latency-per-frame + bytes/bandwidth.
            let m = NetModelConfig::paper_like();
            let want_secs = frames as f64 * m.latency_s + got as f64 / m.gpu_gpu_bw;
            let got_secs = net.link_ns(Link::GpuGpu) as f64 / 1e9;
            assert!(
                (got_secs - want_secs).abs() < 1e-6,
                "k={k}: simulated {got_secs}s vs expected {want_secs}s"
            );
        }
    }

    #[test]
    fn compressed_ring_halves_wire_bytes_within_error_bound() {
        let k = 2;
        let n = 2048usize;
        let mut rng = Rng::new(77);
        let inputs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(n)).collect();
        let exact = reference_mean(&inputs);

        let run = |compress: bool| -> (Vec<Vec<f32>>, u64) {
            let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
            let members = connect_ring_on(k, compress, 5, net.clone());
            let outs = tcp_ring_outputs(members, &inputs);
            (outs, net.link_bytes(Link::GpuGpu))
        };
        let (_, raw_bytes) = run(false);
        let (outs, comp_bytes) = run(true);
        assert!(
            (comp_bytes as f64) < raw_bytes as f64 * 0.7,
            "compression saved nothing: {comp_bytes} vs {raw_bytes}"
        );
        // Lossy, but within a few fp16 quantization steps of the exact mean.
        let norm = exact.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let bound = norm * 2.0f32.powi(-6) + 1e-3;
        for out in &outs {
            for (a, b) in out.iter().zip(&exact) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        }
    }
}
