//! Dense-gradient synchronization (paper §4.2.3, "Optimized communication
//! among NN workers").
//!
//! Persia delegates this to Bagua; offline we implement the same primitives:
//! tensor bucketing + memory flattening ([`bucket`]), ring AllReduce across
//! in-process threads ([`ring`]) and across real OS processes over TCP
//! ([`tcp_ring`], with a rank-0 rendezvous and config-fingerprint
//! handshake), and a naive central-PS reduce baseline ([`central`]) for the
//! ablation bench. The thread and TCP rings share one schedule
//! ([`ring::chunk_range`]) and are bit-identical; [`ring::reference_sum`]
//! replays that deterministic reduction order serially. FullAsync's
//! periodic replica re-centering is NOT a ring collective: it rides the
//! best-effort peer-to-peer [`gossip`] mesh, whose addresses travel in the
//! same rendezvous table.

pub mod bucket;
pub mod central;
pub mod gossip;
pub mod ring;
pub mod tcp_ring;

pub use bucket::FlatBuckets;
pub use central::central_reduce;
pub use gossip::GossipFabric;
pub use ring::RingGroup;
pub use tcp_ring::{RingRendezvous, TcpRingMember};
