//! Dense-gradient synchronization (paper §4.2.3, "Optimized communication
//! among NN workers").
//!
//! Persia delegates this to Bagua; offline we implement the same primitives:
//! tensor bucketing + memory flattening ([`bucket`]), ring AllReduce
//! ([`ring`]), and a naive central-PS reduce baseline ([`central`]) for the
//! ablation bench.

pub mod bucket;
pub mod central;
pub mod ring;

pub use bucket::FlatBuckets;
pub use central::central_reduce;
pub use ring::RingGroup;
