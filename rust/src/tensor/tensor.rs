//! Dense row-major f32 host tensor.
//!
//! Deliberately minimal: the heavy math runs inside the AOT-compiled XLA
//! executables (L2/L1); the host side only needs shaping, elementwise update
//! rules (optimizers), small matmuls for the pure-Rust reference tower, and
//! flat access for the zero-copy wire format.

use std::fmt;

/// Row-major dense f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} el]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Build from existing data; panics on element-count mismatch.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    /// He-style random init (normal * sqrt(2/fan_in)), matching L2's init.
    pub fn he_init(shape: &[usize], rng: &mut crate::util::Rng) -> Self {
        let n: usize = shape.iter().product();
        let fan_in = shape[0].max(1);
        let scale = (2.0 / fan_in as f32).sqrt();
        let data = (0..n).map(|_| rng.normal() * scale).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// 2D accessor (row-major).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        &mut self.data[i * cols + j]
    }

    /// Row view of a 2D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// `self += alpha * other` (elementwise, shapes must match).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale all elements.
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Squared L2 norm.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Matrix multiply: `[m,k] x [k,n] -> [m,n]` (blocked ikj loop).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(other.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner-dim mismatch");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let o_row = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// Transpose a 2D tensor.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn from_vec_validates() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = Rng::new(1);
        let a = Tensor::from_vec(&[5, 7], rng.normal_vec(35));
        let b = Tensor::from_vec(&[7, 3], rng.normal_vec(21));
        let c = a.matmul(&b);
        for i in 0..5 {
            for j in 0..3 {
                let want: f32 = (0..7).map(|k| a.at2(i, k) * b.at2(k, j)).sum();
                assert!((c.at2(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::from_vec(&[4, 6], rng.normal_vec(24));
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]);
        a.axpy(-0.5, &b);
        assert_eq!(a.data(), &[0.5, 1.5, 2.5]);
        a.scale(2.0);
        assert_eq!(a.data(), &[1.0, 3.0, 5.0]);
    }

    #[test]
    fn he_init_scale() {
        let mut rng = Rng::new(3);
        let t = Tensor::he_init(&[512, 16], &mut rng);
        let var = t.sq_norm() / t.len() as f64;
        assert!((var - 2.0 / 512.0).abs() < 1e-3, "var={var}");
    }
}
