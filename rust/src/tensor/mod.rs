//! Host-side tensor primitives: dense f32 tensors and IEEE-754 half floats.

pub mod fp16;
pub mod tensor;

pub use fp16::{f16_to_f32, f32_to_f16};
pub use tensor::Tensor;
