//! IEEE 754 binary16 conversion (the `half` crate is unavailable offline).
//!
//! Used by `comm::compress` for the paper's lossy fp32→fp16 value compression
//! (§4.2.3). Round-to-nearest-even, with correct subnormal, infinity and NaN
//! handling; property-tested against the exact semantics in `comm` tests and
//! against the L1 Pallas `compress` kernel via the AOT artifact.

/// Convert one f32 to its binary16 bit pattern (round-to-nearest-even).
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN: preserve NaN-ness with a quiet bit.
        return if mant == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }

    // Unbiased exponent, rebiased for f16 (bias 15 vs 127).
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal range. 13 mantissa bits are dropped.
        let mant16 = (mant >> 13) as u16;
        let halfexp = ((unbiased + 15) as u16) << 10;
        let mut out = sign | halfexp | mant16;
        // Round to nearest even on the dropped bits.
        let round_bits = mant & 0x1fff;
        if round_bits > 0x1000 || (round_bits == 0x1000 && (mant16 & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent: still correct
        }
        return out;
    }
    if unbiased >= -25 {
        // Subnormal f16.
        let full_mant = mant | 0x0080_0000; // implicit leading 1
        let shift = (-14 - unbiased) as u32 + 13;
        let mant16 = (full_mant >> shift) as u16;
        let mut out = sign | mant16;
        let dropped = full_mant & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if dropped > half || (dropped == half && (mant16 & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    sign // underflow -> signed zero
}

/// Convert one binary16 bit pattern to f32.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;

    let bits = if exp == 0x1f {
        // Inf / NaN
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign // zero
        } else {
            // Subnormal: value = mant * 2^-24. Normalize with s left shifts
            // until bit 10 is set; the f32 biased exponent is then 113 - s.
            let mut s = 0u32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                s += 1;
            }
            m &= 0x3ff;
            sign | ((113 - s) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Largest finite f16 value.
pub const F16_MAX: f32 = 65504.0;

/// Convert a slice, appending to `out` (hot path helper, no allocation).
pub fn compress_slice(src: &[f32], out: &mut Vec<u16>) {
    out.reserve(src.len());
    for &x in src {
        out.push(f32_to_f16(x));
    }
}

/// Convert a u16 slice back to f32, appending to `out`.
pub fn decompress_slice(src: &[u16], out: &mut Vec<f32>) {
    out.reserve(src.len());
    for &h in src {
        out.push(f16_to_f32(h));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(-2.0), 0xc000);
        assert_eq!(f32_to_f16(65504.0), 0x7bff);
        assert_eq!(f32_to_f16(1e9), 0x7c00); // inf
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert!(f16_to_f32(0x7e00).is_nan());
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24)); // smallest subnormal
    }

    #[test]
    fn roundtrip_is_exact_for_f16_representable() {
        // Every f16 bit pattern (finite) must round-trip bit-exactly.
        for h in 0u16..=0xffff {
            let exp = (h >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan handled separately
            }
            let f = f16_to_f32(h);
            assert_eq!(f32_to_f16(f), h, "h={h:#06x} f={f}");
        }
    }

    #[test]
    fn relative_error_within_half_ulp() {
        forall(
            11,
            3000,
            |rng| {
                // Log-uniform magnitude over the f16 normal range.
                let e = rng.range(0, 29) as i32 - 14;
                let m = 1.0 + rng.f32();
                let sign = if rng.bernoulli(0.5) { -1.0 } else { 1.0 };
                sign * m * 2.0f32.powi(e)
            },
            |&x| {
                let back = f16_to_f32(f32_to_f16(x));
                let rel = ((back - x) / x).abs();
                rel <= 2.0f32.powi(-11) + 1e-7
            },
        );
    }

    #[test]
    fn rounding_is_to_nearest_even() {
        // 1.0 + 2^-11 is exactly between 1.0 and 1.0+2^-10: ties-to-even -> 1.0.
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(x), 0x3c00);
        // Slightly above the midpoint rounds up.
        let y = 1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-13);
        assert_eq!(f32_to_f16(y), 0x3c01);
    }

    #[test]
    fn slice_helpers_roundtrip() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let mut h = Vec::new();
        compress_slice(&xs, &mut h);
        let mut back = Vec::new();
        decompress_slice(&h, &mut back);
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 1e-3);
        }
    }
}
