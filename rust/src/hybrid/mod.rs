//! The hybrid training algorithm (paper §3) and its orchestration (§4.1).
//!
//! [`Trainer`] wires loader → embedding workers → NN workers → embedding PS
//! and runs any of the four modes of Fig. 3-right: fully synchronous, fully
//! asynchronous, raw hybrid and optimized hybrid. The worker loop programs
//! against two deployment seams — [`dense_comm::DenseComm`] for the
//! AllReduce fabric (threads or TCP ring) and
//! [`crate::worker::EmbComm`] for the embedding tier (in-process workers or
//! `serve-embedding-worker` processes) — so one loop serves every topology
//! from a single process up to the full three-tier deployment. [`gantt`]
//! records the per-phase timeline that reproduces the figure.

pub mod dense_comm;
pub mod gantt;
pub mod trainer;

pub use dense_comm::{DenseComm, ThreadRing};
pub use gantt::{GanttEvent, GanttTimeline};
pub use trainer::{
    EngineFactory, PjrtEngineFactory, ResumeState, RustEngineFactory, TrainOutput, Trainer,
};
