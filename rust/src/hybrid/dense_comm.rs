//! The dense-synchronization seam: how an NN worker talks to its peers.
//!
//! §4.2.3's "Optimized communication among NN workers" has two deployments
//! in this reproduction — the simulated cluster (one OS thread per worker,
//! mpsc-backed ring) and the real multi-process one (`persia train-worker`,
//! TCP ring). [`DenseComm`] is the seam between them: the trainer's worker
//! loop programs against it, so all four train modes run unchanged whether
//! the ranks share an address space or only a network.
//!
//! Implementations:
//! * [`ThreadRing`] — wraps the in-process
//!   [`RingMember`](crate::allreduce::ring::RingMember) plus the shared
//!   gossip slots FullAsync uses for best-effort replica averaging.
//! * [`TcpRingMember`](crate::allreduce::tcp_ring::TcpRingMember) — the
//!   real-socket ring; its `replica_average` is true peer-to-peer gossip
//!   over the [`GossipFabric`](crate::allreduce::GossipFabric): each rank
//!   posts its replica fire-and-forget and averages whatever arrived, so a
//!   slow or stalled peer never holds up anyone's step (it used to be a
//!   ring AllReduce — a barrier FullAsync exists to avoid).
//!
//! Both expose the ring **ordering token**, which [`ordered`] uses to
//! serialize PS access in rank order — the piece that makes a deterministic
//! FullSync run bit-reproducible across `k` workers, threads or processes.
//! [`DenseComm::replica_average_ordered`] runs the gossip under the same
//! token, extending that guarantee to deterministic FullAsync.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::allreduce::ring::RingMember;
use crate::allreduce::tcp_ring::TcpRingMember;
use crate::allreduce::RingGroup;
use crate::comm::NetSim;
use crate::util::lock_unpoisoned;

/// The dense AllReduce fabric one NN-worker rank holds.
pub trait DenseComm: Send {
    /// This rank's position in `0..world`.
    fn rank(&self) -> usize;
    /// Total ranks in the fabric.
    fn world(&self) -> usize;

    /// In-place AllReduce (mean) of `buf` across all ranks; returns the
    /// simulated communication seconds this rank spent.
    fn all_reduce_mean(&mut self, buf: &mut [f32]) -> Result<f64>;

    /// Pass the deterministic-ordering token to the successor rank.
    fn token_send(&mut self) -> Result<()>;

    /// Receive the deterministic-ordering token from the predecessor rank.
    fn token_recv(&mut self) -> Result<()>;

    /// FullAsync's periodic replica averaging: best-effort gossip —
    /// in-process over shared slots, cross-process over the peer-to-peer
    /// gossip mesh. Never a barrier: a slow peer's replica is simply
    /// missing from the average. Returns simulated communication seconds.
    fn replica_average(&mut self, params: &mut [f32]) -> Result<f64>;

    /// [`DenseComm::replica_average`] run inside a token-ordered section
    /// (same protocol as [`ordered`], inlined here because the section
    /// needs `&mut self` for the averaging itself): ranks post+average
    /// serialized in rank order, so each rank's view of its peers is a
    /// pure function of rank — the deterministic FullAsync variant.
    fn replica_average_ordered(&mut self, params: &mut [f32]) -> Result<f64> {
        if self.world() == 1 {
            return self.replica_average(params);
        }
        if self.rank() > 0 {
            self.token_recv()?;
        }
        let sim = self.replica_average(params)?;
        self.token_send()?;
        if self.rank() == 0 {
            self.token_recv()?;
        }
        Ok(sim)
    }
}

/// Run `f` serialized in rank order 0, 1, ..., k-1: each rank waits for the
/// token from its predecessor, runs `f`, and passes the token on; rank 0
/// starts the cycle and absorbs the fully-cycled token, so when rank 0
/// returns, **every** rank has finished its section. Used by deterministic
/// FullSync to impose one global order on embedding-PS reads and writes.
pub fn ordered<T>(comm: &mut dyn DenseComm, f: impl FnOnce() -> Result<T>) -> Result<T> {
    if comm.world() == 1 {
        return f();
    }
    if comm.rank() > 0 {
        comm.token_recv()?;
    }
    let out = f()?;
    comm.token_send()?;
    if comm.rank() == 0 {
        comm.token_recv()?;
    }
    Ok(out)
}

/// In-process dense fabric: one mpsc ring member per worker thread plus the
/// FullAsync gossip slot array.
pub struct ThreadRing {
    member: RingMember,
    gossip: Arc<Vec<Mutex<Vec<f32>>>>,
}

impl ThreadRing {
    /// Create the `k` connected members of a simulated cluster.
    pub fn group(k: usize, net: Arc<NetSim>) -> Vec<ThreadRing> {
        let gossip: Arc<Vec<Mutex<Vec<f32>>>> =
            Arc::new((0..k).map(|_| Mutex::new(Vec::new())).collect());
        RingGroup::new(k, net)
            .into_iter()
            .map(|member| ThreadRing { member, gossip: gossip.clone() })
            .collect()
    }
}

impl DenseComm for ThreadRing {
    fn rank(&self) -> usize {
        self.member.rank()
    }

    fn world(&self) -> usize {
        self.member.world()
    }

    fn all_reduce_mean(&mut self, buf: &mut [f32]) -> Result<f64> {
        Ok(self.member.all_reduce_mean(buf))
    }

    fn token_send(&mut self) -> Result<()> {
        self.member.send_token()
    }

    fn token_recv(&mut self) -> Result<()> {
        self.member.recv_token()
    }

    fn replica_average(&mut self, params: &mut [f32]) -> Result<f64> {
        // Best-effort gossip: post this replica, average whatever the other
        // replicas have posted so far (paper: FullAsync replicas drift and
        // are only loosely re-centered).
        let rank = self.member.rank();
        *lock_unpoisoned(&self.gossip[rank]) = params.to_vec();
        let mut acc = params.to_vec();
        let mut n = 1.0f32;
        for (i, slot) in self.gossip.iter().enumerate() {
            if i == rank {
                continue;
            }
            let other = lock_unpoisoned(slot);
            if other.len() == acc.len() {
                for (a, o) in acc.iter_mut().zip(other.iter()) {
                    *a += o;
                }
                n += 1.0;
            }
        }
        let inv = 1.0 / n;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        params.copy_from_slice(&acc);
        Ok(0.0)
    }
}

impl DenseComm for TcpRingMember {
    fn rank(&self) -> usize {
        TcpRingMember::rank(self)
    }

    fn world(&self) -> usize {
        TcpRingMember::world(self)
    }

    fn all_reduce_mean(&mut self, buf: &mut [f32]) -> Result<f64> {
        TcpRingMember::all_reduce_mean(self, buf)
    }

    fn token_send(&mut self) -> Result<()> {
        TcpRingMember::send_token(self)
    }

    fn token_recv(&mut self) -> Result<()> {
        TcpRingMember::recv_token(self)
    }

    fn replica_average(&mut self, params: &mut [f32]) -> Result<f64> {
        // True cross-process gossip: post fire-and-forget, average what
        // arrived. A stalled peer costs nothing — its replica is simply
        // absent until it posts again.
        TcpRingMember::gossip_average(self, params)
    }

    fn replica_average_ordered(&mut self, params: &mut [f32]) -> Result<f64> {
        // Same token protocol as the default, but the post is acknowledged
        // by every receiver before the token moves on — so rank r's average
        // sees exactly ranks 0..r of this round plus everyone's previous
        // round, matching the threaded shared-slot gossip bit-for-bit.
        if TcpRingMember::world(self) == 1 {
            return Ok(0.0);
        }
        if TcpRingMember::rank(self) > 0 {
            self.recv_token()?;
        }
        let sim = TcpRingMember::gossip_average_acked(self, params)?;
        self.send_token()?;
        if TcpRingMember::rank(self) == 0 {
            self.recv_token()?;
        }
        Ok(sim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetModelConfig;

    #[test]
    fn ordered_serializes_thread_ring_ranks() {
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let comms = ThreadRing::group(3, net);
        let log = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                let log = log.clone();
                std::thread::spawn(move || {
                    let rank = c.rank();
                    for _ in 0..4 {
                        ordered(&mut c, || {
                            log.lock().unwrap().push(rank);
                            Ok(())
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let got = log.lock().unwrap().clone();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn ordered_is_a_plain_call_for_world_one() {
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let mut comm = ThreadRing::group(1, net).pop().unwrap();
        let out = ordered(&mut comm, || Ok(42)).unwrap();
        assert_eq!(out, 42);
    }

    #[test]
    fn poisoned_gossip_slot_does_not_cascade() {
        // A worker thread that panics while holding a gossip slot must not
        // take every later replica_average down with a PoisonError panic.
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let comms = ThreadRing::group(2, net);
        let mut it = comms.into_iter();
        let mut c0 = it.next().unwrap();
        let c1 = it.next().unwrap();
        let slots = c1.gossip.clone();
        let h = std::thread::spawn(move || {
            let _guard = slots[1].lock().unwrap();
            panic!("die holding rank 1's gossip slot");
        });
        assert!(h.join().is_err(), "the poisoner must have panicked");
        assert!(c1.gossip[1].is_poisoned(), "slot 1 must be poisoned");
        let mut p0 = vec![1.0f32, 3.0];
        c0.replica_average(&mut p0).unwrap();
        // Slot 1 was still empty when poisoned, so rank 0 averages alone.
        assert_eq!(p0, vec![1.0, 3.0]);
    }

    #[test]
    fn thread_ring_replica_average_matches_manual_mean() {
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let comms = ThreadRing::group(2, net);
        // Pre-post rank 1's params so rank 0's average sees them.
        let mut it = comms.into_iter();
        let mut c0 = it.next().unwrap();
        let mut c1 = it.next().unwrap();
        let mut p1 = vec![3.0f32, 5.0];
        c1.replica_average(&mut p1).unwrap(); // posts [3, 5]; averages alone
        assert_eq!(p1, vec![3.0, 5.0]);
        let mut p0 = vec![1.0f32, 1.0];
        c0.replica_average(&mut p0).unwrap();
        assert_eq!(p0, vec![2.0, 3.0]);
    }
}
