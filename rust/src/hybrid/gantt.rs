//! Per-phase timeline recording (reproduces paper Fig. 3-right).
//!
//! The trainer computes, per step and mode, when each of the five stages
//! (embedding preparation, forward, backward, dense sync, embedding update)
//! starts and how long it runs on the *simulated* clock — including which
//! stages overlap. The fig3 bench renders these as ASCII Gantt rows.

/// One phase occurrence on the timeline.
#[derive(Clone, Debug)]
pub struct GanttEvent {
    /// Training step the phase belongs to.
    pub step: u64,
    /// Phase name (one of [`PHASES`]).
    pub phase: &'static str,
    /// Simulated start time (seconds from run start).
    pub start: f64,
    /// Simulated duration in seconds.
    pub dur: f64,
}

/// Ordered event log for one run.
#[derive(Clone, Debug, Default)]
pub struct GanttTimeline {
    /// Every recorded phase occurrence, in push order.
    pub events: Vec<GanttEvent>,
}

/// The five pipeline stages of one training step (Fig. 3 row order).
pub const PHASES: [&str; 5] = ["emb_prep", "forward", "backward", "dense_sync", "emb_update"];

impl GanttTimeline {
    /// Record one phase occurrence.
    pub fn push(&mut self, step: u64, phase: &'static str, start: f64, dur: f64) {
        self.events.push(GanttEvent { step, phase, start, dur });
    }

    /// Simulated end time of the latest-finishing event.
    pub fn total_span(&self) -> f64 {
        self.events.iter().map(|e| e.start + e.dur).fold(0.0, f64::max)
    }

    /// Render rows of `width` columns, one per phase, `[###]` = busy.
    pub fn render_ascii(&self, width: usize) -> String {
        let span = self.total_span();
        if span <= 0.0 || self.events.is_empty() {
            return String::from("(empty timeline)\n");
        }
        let mut out = String::new();
        for phase in PHASES {
            let mut row = vec![b' '; width];
            for e in self.events.iter().filter(|e| e.phase == phase) {
                let a = ((e.start / span) * width as f64) as usize;
                let b = (((e.start + e.dur) / span) * width as f64).ceil() as usize;
                for c in row.iter_mut().take(b.min(width)).skip(a.min(width)) {
                    *c = b'#';
                }
            }
            out.push_str(&format!("{:<11}|{}|\n", phase, String::from_utf8(row).unwrap()));
        }
        out.push_str(&format!("{:<11} 0 {:->width$.4}s\n", "", span, width = width - 2));
        out
    }

    /// Fraction of the span during which >= 2 phases run concurrently —
    /// the overlap the hybrid modes exist to create.
    pub fn overlap_fraction(&self) -> f64 {
        let span = self.total_span();
        if span <= 0.0 {
            return 0.0;
        }
        let n = 1000;
        let mut overlapped = 0usize;
        for i in 0..n {
            let t = span * (i as f64 + 0.5) / n as f64;
            let busy = self
                .events
                .iter()
                .filter(|e| e.start <= t && t < e.start + e.dur)
                .count();
            if busy >= 2 {
                overlapped += 1;
            }
        }
        overlapped as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_and_render() {
        let mut t = GanttTimeline::default();
        t.push(0, "emb_prep", 0.0, 1.0);
        t.push(0, "forward", 1.0, 2.0);
        assert_eq!(t.total_span(), 3.0);
        let art = t.render_ascii(30);
        assert!(art.contains("emb_prep"));
        assert!(art.contains('#'));
    }

    #[test]
    fn overlap_fraction_detects_concurrency() {
        let mut serial = GanttTimeline::default();
        serial.push(0, "forward", 0.0, 1.0);
        serial.push(0, "dense_sync", 1.0, 1.0);
        assert!(serial.overlap_fraction() < 0.01);

        let mut overlapped = GanttTimeline::default();
        overlapped.push(0, "forward", 0.0, 2.0);
        overlapped.push(0, "dense_sync", 0.0, 2.0);
        assert!(overlapped.overlap_fraction() > 0.95);
    }

    #[test]
    fn empty_timeline_renders() {
        let t = GanttTimeline::default();
        assert!(t.render_ascii(20).contains("empty"));
    }
}
