//! The end-to-end distributed trainer (paper §4.1's data-dispatching
//! procedure, steps (1)-(7)) in all four synchronization modes.
//!
//! Topology (one OS thread per logical node — see DESIGN.md substitutions).
//! The embedding PS sits behind [`PsBackend`]: in-process by default, or a
//! remote TCP server when [`Trainer::ps_backend`] is set to a
//! [`crate::service::RemotePs`] (the TCP service mode in `service/`); all
//! four modes run unchanged against either. The dense AllReduce fabric
//! likewise sits behind [`DenseComm`]: [`Trainer::run`] wires the simulated
//! cluster (one thread per rank, mpsc ring), while [`Trainer::run_rank`]
//! runs a single rank whose ring peers are other OS **processes**
//! (`persia train-worker`, TCP ring) — the fully multi-process hybrid
//! deployment: data loaders + NN workers × PS shards.
//!
//! ```text
//!   loader(rank r) ──ids──▶ embedding worker ──get/put──▶ embedding PS
//!        │                        ▲      │
//!        └──nid,label──▶ NN worker│◀─emb─┘        NN worker ◀─ring─▶ peers
//!                        (one thread per rank, Alg. 2 + AllReduce)
//! ```
//!
//! Mode semantics (Fig. 3-right):
//! * `FullSync` — all five stages sequential; embedding gradients applied
//!   inline before the next pull (τ = 0).
//! * `HybridRaw` — embedding get/put async with a prefetch pipeline bounded
//!   by τ (`staleness_bound`); dense AllReduce still a separate barrier.
//! * `Hybrid` — + dense AllReduce overlapped with backward (simulated-clock
//!   overlap; the paper does this with Bagua's fused bucket schedule).
//! * `FullAsync` — no dense barrier at all: each worker steps its own
//!   replica and replicas are gossip-averaged only every
//!   [`Trainer::gossip_period`] steps (best-effort gossip in both
//!   deployments — shared slots in-process, the peer-to-peer
//!   [`GossipFabric`](crate::allreduce::GossipFabric) across processes);
//!   embedding staleness unbounded (2τ pipeline). Statistical efficiency
//!   drops — exactly the paper's argument for hybrid.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::comm::NetSim;
use crate::config::{ClusterConfig, EmbeddingConfig, ModelConfig, Pooling, TrainConfig, TrainMode};
use crate::data::sample::SampleId;
use crate::data::SyntheticDataset;
use crate::dense::{DenseModel, DenseOptimizer, DenseOptimizerKind};
use crate::embedding::{CheckpointManager, EmbeddingPs, StoreConfig};
use crate::metrics::{auc, RunReport, Tracker};
use crate::recovery::{run_epoch, EpochConfig, GlobalManifest, RetryPolicy};
use crate::runtime::{ArtifactManifest, DenseEngine, PjRtRuntime};
use crate::service::reshard::ReshardConfig;
use crate::service::PsBackend;
use crate::util::Rng;
use crate::worker::{EmbComm, EwCacheConfig, EwCacheParams, LocalEmbTier};

use super::dense_comm::{ordered, DenseComm, ThreadRing};
use super::gantt::GanttTimeline;

/// Default for [`Trainer::gossip_period`] — how often FullAsync
/// gossip-averages the dense replicas.
const DEFAULT_GOSSIP_PERIOD: u64 = 64;

/// Total tries an async gradient applier gives one put. A failed
/// `push_grads` re-buffers its samples, so each retry replays the exact
/// same batch; combined with the remote backend's own reconnect-with-retry
/// (the shared `recovery` pool) this rides out a PS shard process being
/// killed and restarted (§4.2.4).
const PUT_ATTEMPTS: u32 = 3;

/// Per-worker dense-engine construction. PJRT executables are not `Send`
/// (the `xla` crate wraps raw PJRT pointers), so every NN-worker thread
/// builds and owns its engine — exactly the paper's topology, where each GPU
/// worker holds its own compiled graph.
pub trait EngineFactory: Sync {
    /// Build the dense engine rank `rank` will own.
    fn create(&self, rank: usize) -> Result<DenseEngine>;
}

/// Factory for the pure-Rust reference tower.
pub struct RustEngineFactory {
    /// Identically-initialized model every rank clones (replicas start equal).
    pub template: DenseModel,
}

impl EngineFactory for RustEngineFactory {
    fn create(&self, _rank: usize) -> Result<DenseEngine> {
        Ok(DenseEngine::rust(self.template.clone()))
    }
}

/// Factory loading the AOT artifacts via a per-thread PJRT CPU client.
pub struct PjrtEngineFactory {
    /// Directory holding the AOT artifact manifest + HLO files.
    pub artifacts_dir: std::path::PathBuf,
    /// Artifact preset name ("tiny" | "small" | "paper").
    pub preset: String,
}

impl EngineFactory for PjrtEngineFactory {
    fn create(&self, _rank: usize) -> Result<DenseEngine> {
        let rt = PjRtRuntime::cpu()?;
        let manifest = ArtifactManifest::load(&self.artifacts_dir)?;
        DenseEngine::pjrt(&rt, &manifest, &self.preset)
    }
}

/// Dense-side state a resumed run restores before its first step — decoded
/// from a committed [`GlobalManifest`] by the caller (`persia train
/// --resume-from`), or built by tests. PS state is restored separately:
/// in-process via `ps_restore`, remote shards by their own
/// `--checkpoint-dir` at startup.
#[derive(Clone, Debug)]
pub struct ResumeState {
    /// Dense optimizer kind code recorded at the epoch (must match the
    /// run's configured optimizer).
    pub opt_kind: u64,
    /// Dense optimizer step counter at the epoch.
    pub opt_t: u64,
    /// Dense parameters at the epoch boundary.
    pub params: Vec<f32>,
    /// Optimizer first moments (empty for SGD).
    pub opt_m: Vec<f32>,
    /// Optimizer second moments (empty for SGD/momentum).
    pub opt_v: Vec<f32>,
    /// When `Some`, the in-process PS restores from this checkpoint root's
    /// epoch [`Trainer::start_step`] before training; `None` means a remote
    /// deployment already restored itself.
    pub ps_restore: Option<std::path::PathBuf>,
}

impl ResumeState {
    /// Build from a committed global manifest (plus where the in-process PS
    /// should restore from, if anywhere).
    pub fn from_manifest(m: &GlobalManifest, ps_restore: Option<std::path::PathBuf>) -> Self {
        Self {
            opt_kind: m.opt_kind,
            opt_t: m.opt_t,
            params: m.params.clone(),
            opt_m: m.opt_m.clone(),
            opt_v: m.opt_v.clone(),
            ps_restore,
        }
    }
}

/// Result of a training run.
pub struct TrainOutput {
    /// Aggregate run metrics (loss/AUC/throughput/staleness).
    pub report: RunReport,
    /// Worker-0 loss/AUC curves + phase histograms.
    pub tracker: Tracker,
    /// Worker-0 simulated-clock phase timeline (Fig. 3).
    pub gantt: GanttTimeline,
    /// PS imbalance statistic (load-balance ablation).
    pub ps_imbalance: f64,
    /// Worker-0's final dense parameters (flat artifact order).
    pub final_params: Vec<f32>,
}

/// One prefetched, embedding-complete mini-batch (a
/// [`PreparedBatch`](crate::worker::PreparedBatch) from the embedding tier
/// plus the staleness observed at pull time).
struct Prefetched {
    ew: usize,
    sids: Vec<SampleId>,
    emb: Vec<f32>,
    nid: Vec<f32>,
    labels: Vec<f32>,
    /// Simulated seconds spent preparing it (PS fetch + transfers).
    sim_prep: f64,
    /// Embedding staleness (pending unapplied grad batches at pull time).
    staleness: u64,
}

/// Work item for the async gradient-applier threads.
enum GradMsg {
    Apply { ew: usize, sids: Vec<SampleId>, grads: Vec<f32> },
    Stop,
}

/// What one rank's worker loop leaves behind:
/// `(tracker, gantt, final params, wall secs, simulated extra secs)`.
type RankRun = (Tracker, GanttTimeline, Vec<f32>, f64, f64);

/// Everything one training process builds besides its NN-worker rank(s):
/// the embedding tier (in-process workers over a PS backend, or a remote
/// [`crate::service::RemoteEmbTier`]) and the gradient-applier threads.
/// Shared by the all-threads deployment ([`Trainer::run`]) and the
/// one-rank-per-process deployment ([`Trainer::run_rank`]).
struct RunCtx {
    net: Arc<NetSim>,
    tier: Arc<dyn EmbComm>,
    appliers: Vec<Sender<GradMsg>>,
    applier_handles: Vec<std::thread::JoinHandle<()>>,
    inflight: Arc<Vec<AtomicI64>>,
    max_staleness: Arc<AtomicU64>,
    put_failures: Arc<AtomicU64>,
    init_params: Vec<f32>,
}

/// The distributed trainer.
pub struct Trainer {
    /// Dense-tower + feature geometry.
    pub model: ModelConfig,
    /// Embedding-PS storage geometry.
    pub emb_cfg: EmbeddingConfig,
    /// Cluster shape: NN workers, embedding workers, network model.
    pub cluster: ClusterConfig,
    /// Train-loop parameters (mode, batch, steps, seeds, ...).
    pub train: TrainConfig,
    /// The synthetic CTR stream every rank draws from.
    pub dataset: SyntheticDataset,
    /// Evaluation batch rows for AUC.
    pub eval_rows: usize,
    /// Record a Gantt timeline on worker 0.
    pub record_gantt: bool,
    /// PS backend override. `None` builds the in-process [`EmbeddingPs`]
    /// from `emb_cfg`; `Some` (a [`crate::service::RemotePs`] or a
    /// multi-process [`crate::service::ShardedRemotePs`]) trains against
    /// it — the TCP service mode. Ignored when [`Trainer::emb_comm`] is set
    /// (the remote embedding workers own the PS connection then).
    pub ps_backend: Option<Arc<dyn PsBackend>>,
    /// Embedding-tier override. `None` builds the in-process
    /// [`LocalEmbTier`] (workers as plain structs over `ps_backend`);
    /// `Some` (a [`crate::service::RemoteEmbTier`]) trains against
    /// out-of-process `serve-embedding-worker` processes — the paper's full
    /// three-tier topology. Validated against
    /// [`Trainer::config_fingerprint`] at run start.
    pub emb_comm: Option<Arc<dyn EmbComm>>,
    /// Apply embedding gradients inline (single-threaded per worker) instead
    /// of via the async applier threads. The prefetch pipeline still runs τ
    /// batches ahead, so bounded staleness is preserved, but the whole run
    /// becomes bit-reproducible — the loopback service test relies on this
    /// to assert exact in-process vs. remote parity. With more than one NN
    /// worker this requires `FullSync` or `FullAsync` mode: the ring's
    /// ordering token then serializes every PS read/write (and FullAsync's
    /// replica gossip) in rank order (see [`super::dense_comm::ordered`]),
    /// which is what lets a multi-process `train-worker` deployment be
    /// proven numerically identical to the threaded run.
    pub deterministic: bool,
    /// FullAsync re-centers the drifting dense replicas every this many
    /// steps (`--gossip-period`; best-effort gossip, token-ordered acked
    /// gossip when `deterministic`). Ignored by the other modes.
    pub gossip_period: u64,
    /// Cut coordinated checkpoint epochs (`--checkpoint-dir` +
    /// `--checkpoint-every`): rank 0 drives the two-phase PREPARE/COMMIT
    /// across the PS deployment at every `every`-step boundary and writes
    /// the global manifest — see [`crate::recovery::coordinator`]. In
    /// ordered deterministic mode the drive is a collective ordered
    /// section, so the snapshot is the *exact* boundary state.
    pub checkpoint: Option<EpochConfig>,
    /// Probe for live PS resharding (`--reshard-every` +
    /// `--reshard-threshold`): rank 0 merges the fleet's per-node traffic
    /// at every `every`-step boundary and, when the per-process imbalance
    /// exceeds the threshold, drives a split/migrate round through
    /// [`EmbComm::maybe_reshard`] — see [`crate::service::reshard`]. Only
    /// meaningful against a [`crate::service::ShardedRemotePs`] backend;
    /// other tiers ignore the probe. Pair the cadence with
    /// `checkpoint.every` (a multiple) so every committed reshard is
    /// immediately followed by a checkpoint of the new layout.
    pub reshard: Option<ReshardConfig>,
    /// First step index to train (`--resume-from`): the run behaves as if
    /// steps `0..start_step` already happened — loader streams fast-forward
    /// and the loop starts here. 0 for a fresh run.
    pub start_step: usize,
    /// Dense/optimizer state restored before the first step (a resumed
    /// run); `None` starts from the seed-derived init.
    pub resume: Option<ResumeState>,
    /// Storage engine for the in-process PS (`--cold-dir`/`--hot-capacity`):
    /// the default all-hot LRU, or a tiered hot-over-disk store. Deliberately
    /// NOT part of [`Trainer::config_fingerprint`] — with a cold tier,
    /// placement never changes row bytes, so this is a serving knob, not
    /// deployment identity. Ignored when `ps_backend`/`emb_comm` is set (the
    /// remote processes pick their own engines via `serve-ps` flags).
    pub store: StoreConfig,
    /// Bounded-staleness hot-embedding cache at the (in-process) embedding
    /// workers (`--ew-cache*`), `None` = off. On by default, but **forced
    /// off in deterministic mode** — [`Trainer::ew_cache_params`] refuses to
    /// resolve it there, so every bitwise-parity claim holds by
    /// construction. Like [`Trainer::store`], deliberately NOT part of
    /// [`Trainer::config_fingerprint`]: within the mode's staleness
    /// contract the cache changes *when* rows are read, never what a row's
    /// bytes mean, so it is a serving knob, not deployment identity.
    /// Ignored when `emb_comm` is set (remote workers build their own cache
    /// from their `--ew-cache*` flags).
    pub ew_cache: Option<EwCacheConfig>,
}

impl Trainer {
    /// A trainer with default eval size and no deployment overrides.
    pub fn new(
        model: ModelConfig,
        emb_cfg: EmbeddingConfig,
        cluster: ClusterConfig,
        train: TrainConfig,
        dataset: SyntheticDataset,
    ) -> Self {
        Self {
            model,
            emb_cfg,
            cluster,
            train,
            dataset,
            eval_rows: 2048,
            record_gantt: false,
            ps_backend: None,
            emb_comm: None,
            deterministic: false,
            gossip_period: DEFAULT_GOSSIP_PERIOD,
            checkpoint: None,
            reshard: None,
            start_step: 0,
            resume: None,
            store: StoreConfig::default(),
            ew_cache: Some(EwCacheConfig::default()),
        }
    }

    /// Pipeline depth (bounded staleness τ) for the configured mode — how
    /// many batches each rank's lookahead keeps in flight beyond the one
    /// being trained on.
    pub fn pipeline_depth(&self) -> usize {
        match self.train.mode {
            TrainMode::FullSync => 0,
            TrainMode::HybridRaw | TrainMode::Hybrid => self.train.staleness_bound,
            TrainMode::FullAsync => self.train.staleness_bound * 2,
        }
    }

    /// Resolve [`Trainer::ew_cache`] into per-worker construction
    /// parameters, or `None` when the cache must not exist: deterministic
    /// mode (bitwise parity — never constructing it is what makes the
    /// cache a strict no-op there) or `--ew-cache false`. The default
    /// staleness budget is the run's own bound τ; the push policy follows
    /// the embedding optimizer (SGD mirrors, stateful ones invalidate).
    pub fn ew_cache_params(&self) -> Option<EwCacheParams> {
        if self.deterministic {
            return None;
        }
        let cfg = self.ew_cache.as_ref()?;
        let tau = self.train.staleness_bound.max(1) as u64;
        // Steps → fetch-tick conversion: a worker serves about
        // ceil(n_ranks / n_ew) rank-batches per global step.
        let n_ew = self.cluster.n_emb_workers.max(1);
        let ranks_per_worker = (self.cluster.n_nn_workers + n_ew - 1) / n_ew;
        Some(EwCacheParams::resolve(
            cfg,
            tau,
            ranks_per_worker.max(1),
            self.emb_cfg.optimizer,
            self.emb_cfg.lr,
        ))
    }

    /// The pure-Rust engine factory (deterministic template init derived
    /// from the train seed) — public so multi-process entry points can pair
    /// it with [`Trainer::run_rank`].
    pub fn rust_engine_factory(&self) -> RustEngineFactory {
        let mut rng = Rng::new(self.train.seed ^ 0xE17);
        let template =
            DenseModel::new(&self.model.dims(), self.model.emb_dim(), self.model.nid_dim, &mut rng);
        RustEngineFactory { template }
    }

    /// Convenience: run with the pure-Rust engine.
    pub fn run_rust(&self) -> Result<TrainOutput> {
        self.run(&self.rust_engine_factory())
    }

    /// FNV-1a digest of every configuration knob that changes this run's
    /// numerics (model/embedding geometry, optimizer setup, train loop
    /// shape, seeds, world size). The `train-worker` rendezvous exchanges
    /// it exactly like the PS INFO fingerprint: ranks whose configs differ
    /// are rejected at connect time instead of silently training different
    /// models that can never be bit-compared.
    pub fn config_fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut put = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        put(self.model.n_groups as u64);
        put(self.model.emb_dim_per_group as u64);
        put(self.model.nid_dim as u64);
        put(self.model.hidden.len() as u64);
        for &w in &self.model.hidden {
            put(w as u64);
        }
        put(self.model.ids_per_group as u64);
        put(match self.model.pooling {
            Pooling::Sum => 0,
            Pooling::Mean => 1,
        });
        put(self.emb_cfg.rows_per_group);
        put(self.emb_cfg.shard_capacity as u64);
        put(self.emb_cfg.n_nodes as u64);
        put(self.emb_cfg.shards_per_node as u64);
        put(crate::service::protocol::optimizer_code(self.emb_cfg.optimizer));
        put(crate::service::protocol::partition_code(self.emb_cfg.partition));
        put(u64::from(self.emb_cfg.lr.to_bits()));
        put(self.cluster.n_nn_workers as u64);
        put(self.cluster.n_emb_workers as u64);
        put(match self.train.mode {
            TrainMode::FullSync => 0,
            TrainMode::FullAsync => 1,
            TrainMode::HybridRaw => 2,
            TrainMode::Hybrid => 3,
        });
        put(self.train.batch_size as u64);
        put(u64::from(self.train.lr.to_bits()));
        put(self.train.staleness_bound as u64);
        put(self.train.steps as u64);
        put(self.train.eval_every as u64);
        put(self.train.seed);
        put(u64::from(self.train.use_pjrt));
        put(u64::from(self.train.compress));
        put(self.dataset.numeric_fingerprint());
        put(self.eval_rows as u64);
        put(u64::from(self.deterministic));
        drop(put);
        h
    }

    /// Shared config validation for [`Trainer::run`] and
    /// [`Trainer::run_rank`].
    fn validate_cfg(&self) -> Result<()> {
        self.model.validate()?;
        self.emb_cfg.validate()?;
        self.cluster.validate()?;
        self.train.validate()?;
        // Bit-reproducibility with k > 1 needs a global order on the shared
        // PS, which the ring token can impose on FullSync's per-step
        // structure and on FullAsync (ordered prefetch + inline ordered
        // push + token-ordered acked gossip). The hybrid modes' applier
        // threads stay single-worker.
        anyhow::ensure!(
            !self.deterministic
                || self.cluster.n_nn_workers == 1
                || self.train.mode == TrainMode::FullSync
                || self.train.mode == TrainMode::FullAsync,
            "deterministic mode requires n_nn_workers == 1 or --mode sync/async \
             (got {} workers, mode {})",
            self.cluster.n_nn_workers,
            self.train.mode.name()
        );
        anyhow::ensure!(
            self.gossip_period >= 1,
            "--gossip-period must be >= 1 (got {})",
            self.gossip_period
        );
        anyhow::ensure!(
            self.start_step < self.train.steps,
            "resume start step {} is not before the configured {} total steps — \
             the checkpointed run already finished",
            self.start_step,
            self.train.steps
        );
        if let Some(ck) = &self.checkpoint {
            ck.validate()?;
        }
        if let Some(rs) = &self.reshard {
            rs.validate()?;
        }
        if let Some(r) = &self.resume {
            anyhow::ensure!(
                r.opt_kind == 0,
                "resume manifest records dense optimizer code {}, this trainer runs SGD (0)",
                r.opt_kind
            );
        }
        Ok(())
    }

    /// Build everything one training process needs besides its NN-worker
    /// rank(s): the embedding tier (validated against this config) and the
    /// async gradient-applier threads.
    fn setup(&self) -> Result<RunCtx> {
        let net = Arc::new(NetSim::new(self.cluster.net));
        let tier: Arc<dyn EmbComm> = match &self.emb_comm {
            Some(tier) => {
                // Remote embedding workers built from different flags than
                // this trainer would silently train different numerics;
                // fail like the PS handshake instead.
                tier.check_compat(self.config_fingerprint())?;
                anyhow::ensure!(
                    tier.n_workers() == self.cluster.n_emb_workers,
                    "embedding tier has {} worker(s), cluster config says {} — \
                     n_emb_workers must equal the tier's process count",
                    tier.n_workers(),
                    self.cluster.n_emb_workers
                );
                tier.clone()
            }
            None => {
                let backend: Arc<dyn PsBackend> = match &self.ps_backend {
                    Some(backend) => backend.clone(),
                    None => {
                        let local = Arc::new(
                            EmbeddingPs::new_with_store(
                                &self.emb_cfg,
                                self.model.emb_dim_per_group,
                                self.train.seed,
                                &self.store,
                            )
                            .context("building the in-process embedding PS")?,
                        );
                        // A resumed in-process run restores its PS from the
                        // committed epoch it is resuming at (remote shards
                        // restore themselves at process start instead).
                        if let Some(dir) =
                            self.resume.as_ref().and_then(|r| r.ps_restore.as_ref())
                        {
                            let mgr = CheckpointManager::new(dir)?;
                            mgr.restore_epoch(&local, self.start_step as u64).with_context(
                                || {
                                    format!(
                                        "restoring in-process PS from epoch {} under {}",
                                        self.start_step,
                                        dir.display()
                                    )
                                },
                            )?;
                        }
                        local
                    }
                };
                anyhow::ensure!(
                    backend.dim() == self.model.emb_dim_per_group,
                    "PS backend dim {} != model group dim {}",
                    backend.dim(),
                    self.model.emb_dim_per_group
                );
                // A remote PS built from different flags than this trainer
                // would silently train different numerics; fail the
                // handshake instead.
                backend.check_compat(&self.emb_cfg, self.train.seed)?;
                Arc::new(LocalEmbTier::new(
                    self.dataset.clone(),
                    &self.model,
                    backend,
                    net.clone(),
                    self.train.compress,
                    self.cluster.n_emb_workers,
                    self.cluster.n_nn_workers,
                    self.train.batch_size,
                    self.ew_cache_params(),
                ))
            }
        };

        // A resumed run: every rank's loader stream must already stand at
        // the resume boundary before the first NEXT_BATCH (the remote tier
        // fast-forwards in its own processes via --start-step; its no-op
        // here is backstopped by the strict sequential step check).
        if self.start_step > 0 {
            for r in 0..self.cluster.n_nn_workers {
                tier.fast_forward(r, self.start_step).with_context(|| {
                    format!("fast-forwarding rank {r} to resume step {}", self.start_step)
                })?;
            }
        }

        // Async gradient appliers: one thread per embedding worker; the
        // in-flight counter per worker is the measured staleness.
        let n_ew = tier.n_workers();
        let inflight: Arc<Vec<AtomicI64>> =
            Arc::new((0..n_ew).map(|_| AtomicI64::new(0)).collect());
        let max_staleness = Arc::new(AtomicU64::new(0));
        let put_failures = Arc::new(AtomicU64::new(0));
        let mut applier_handles = Vec::with_capacity(n_ew);
        let appliers: Vec<Sender<GradMsg>> = (0..n_ew)
            .map(|applier_idx| {
                let tier = tier.clone();
                let inflight = inflight.clone();
                let put_failures = put_failures.clone();
                let (tx, rx) = channel::<GradMsg>();
                let handle = std::thread::Builder::new()
                    .name(format!("grad-applier-{applier_idx}"))
                    .spawn(move || {
                        // The shared recovery policy: a failed push
                        // re-buffers its samples, so each retry replays the
                        // exact same batch (a killed PS shard may be
                        // restarting under it). Backoff lives in the wire
                        // client's own reconnect loop, so none is added
                        // here.
                        let retry = RetryPolicy::new(PUT_ATTEMPTS - 1, 0);
                        while let Ok(msg) = rx.recv() {
                            match msg {
                                GradMsg::Apply { ew: idx, sids, grads } => {
                                    // Losing a put after the retry budget is
                                    // tolerated (§4.2.4), but never
                                    // silently: count it and surface the
                                    // first failure.
                                    let res = retry.run("async gradient put", || {
                                        tier.push_grads(idx, &sids, &grads)
                                    });
                                    if let Err(e) = res {
                                        // Give the batch up for good: drop
                                        // the re-buffered samples so a dead
                                        // shard doesn't grow the buffer
                                        // without bound (§4.2.4 tolerates
                                        // the lost update, not the leak).
                                        tier.discard(idx, &sids);
                                        if put_failures.fetch_add(1, Ordering::Relaxed) == 0 {
                                            eprintln!(
                                                "grad applier: put failed \
                                                 ({PUT_ATTEMPTS} attempts): {e:#}"
                                            );
                                        }
                                    }
                                    inflight[idx].fetch_sub(1, Ordering::Relaxed);
                                }
                                GradMsg::Stop => return,
                            }
                        }
                    })
                    .expect("spawn applier");
                applier_handles.push(handle);
                tx
            })
            .collect();

        // Identical dense init on every worker (paper: replicas start equal).
        let mut init_rng = Rng::new(self.train.seed ^ 0xD15E);
        let dims = self.model.dims();
        let init_model =
            DenseModel::new(&dims, self.model.emb_dim(), self.model.nid_dim, &mut init_rng);
        let init_params = init_model.params_flat();

        Ok(RunCtx {
            net,
            tier,
            appliers,
            applier_handles,
            inflight,
            max_staleness,
            put_failures,
            init_params,
        })
    }

    /// Drain the appliers (queued puts apply in order before Stop) so the
    /// failure count is complete and no thread outlives the run.
    fn stop_appliers(
        appliers: Vec<Sender<GradMsg>>,
        handles: Vec<std::thread::JoinHandle<()>>,
    ) {
        for tx in &appliers {
            let _ = tx.send(GradMsg::Stop);
        }
        drop(appliers);
        for handle in handles {
            let _ = handle.join();
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn build_output(
        &self,
        tier: &Arc<dyn EmbComm>,
        tracker: Tracker,
        gantt: GanttTimeline,
        final_params: Vec<f32>,
        wall_secs: f64,
        sim_extra: f64,
        max_staleness: u64,
        grad_put_failures: u64,
    ) -> TrainOutput {
        let k = self.cluster.n_nn_workers;
        // Samples actually trained by THIS run (a resumed run re-trains
        // only the steps after its checkpoint epoch).
        let samples = ((self.train.steps - self.start_step) * self.train.batch_size * k) as u64;
        // Simulated time = real compute wall time + injected network time
        // (which threads did not actually sleep through).
        let sim_secs = wall_secs + sim_extra;
        let report = RunReport {
            mode: self.train.mode.name().to_string(),
            steps: self.train.steps as u64,
            samples,
            wall_secs,
            sim_secs,
            final_loss: tracker.recent_loss(20).unwrap_or(f32::NAN),
            final_auc: tracker.final_auc(),
            samples_per_sec: samples as f64 / sim_secs.max(1e-9),
            max_staleness,
            grad_put_failures,
        };
        let ps_imbalance = tier.ps_stats().map(|s| s.imbalance).unwrap_or(f64::NAN);
        // One merged worker-cache line per run (absent when uncached), so
        // operators — and the integration drills — can see the hit mix
        // without scraping per-worker stats.
        if let Some(cs) = tier.cache_stats() {
            if cs.any() {
                eprintln!(
                    "EW-CACHE: hits={} coalesced={} misses={} stale_refreshes={} \
                     updates={} invalidations={} evictions={} flushes={} saved_bytes={}",
                    cs.hits,
                    cs.coalesced,
                    cs.misses,
                    cs.stale_refreshes,
                    cs.updates,
                    cs.invalidations,
                    cs.evictions,
                    cs.flushes,
                    cs.bytes_saved(self.model.emb_dim_per_group)
                );
            }
        }
        TrainOutput { report, tracker, gantt, ps_imbalance, final_params }
    }

    /// Run the configured training; `factory` builds each worker's dense
    /// engine (PJRT artifacts or the pure-Rust tower). This is the
    /// simulated-cluster deployment: every NN-worker rank is a thread of
    /// this process, connected by the in-process [`ThreadRing`].
    pub fn run<F: EngineFactory>(&self, factory: &F) -> Result<TrainOutput> {
        self.validate_cfg()?;
        let ctx = self.setup()?;
        let k = self.cluster.n_nn_workers;
        let comms = ThreadRing::group(k, ctx.net.clone());

        let trackers: Vec<Mutex<Tracker>> = (0..k).map(|_| Mutex::new(Tracker::new())).collect();
        let gantts: Vec<Mutex<GanttTimeline>> =
            (0..k).map(|_| Mutex::new(GanttTimeline::default())).collect();
        let sim_clocks: Vec<AtomicU64> = (0..k).map(|_| AtomicU64::new(0)).collect();
        let wall_start = std::time::Instant::now();
        let final_params: Vec<Mutex<Vec<f32>>> = (0..k).map(|_| Mutex::new(Vec::new())).collect();

        let out: Result<Vec<()>> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (rank, comm) in comms.into_iter().enumerate() {
                let tier = ctx.tier.clone();
                // mpsc Senders are Send but not Sync: clone per thread.
                let appliers: Vec<Sender<GradMsg>> = ctx.appliers.clone();
                let inflight = ctx.inflight.clone();
                let max_staleness = ctx.max_staleness.clone();
                let init_params = ctx.init_params.clone();
                let trackers = &trackers;
                let gantts = &gantts;
                let sim_clocks = &sim_clocks;
                let final_params = &final_params;
                handles.push(scope.spawn(move || -> Result<()> {
                    let mut comm = comm;
                    let engine = factory.create(rank)?;
                    if let Some(eb) = engine.train_batch() {
                        anyhow::ensure!(
                            eb == self.train.batch_size,
                            "engine batch {eb} != configured batch {}",
                            self.train.batch_size
                        );
                    }
                    self.worker_loop(
                        rank,
                        &mut comm,
                        engine,
                        &tier,
                        &appliers,
                        &inflight,
                        &max_staleness,
                        init_params,
                        &trackers[rank],
                        &gantts[rank],
                        &sim_clocks[rank],
                        &final_params[rank],
                    )
                }));
            }
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        out?;

        Self::stop_appliers(ctx.appliers, ctx.applier_handles);

        let wall_secs = wall_start.elapsed().as_secs_f64();
        let sim_extra = sim_clocks
            .iter()
            .map(|c| c.load(Ordering::Relaxed) as f64 / 1e9)
            .fold(0.0, f64::max);
        let tracker = trackers[0].lock().unwrap().take_inner();
        let gantt = gantts[0].lock().unwrap().clone();
        let fp = std::mem::take(&mut *final_params[0].lock().unwrap());
        Ok(self.build_output(
            &ctx.tier,
            tracker,
            gantt,
            fp,
            wall_secs,
            sim_extra,
            ctx.max_staleness.load(Ordering::Relaxed),
            ctx.put_failures.load(Ordering::Relaxed),
        ))
    }

    /// Run exactly ONE NN-worker rank of a multi-process deployment on the
    /// calling thread. `make_comm` receives this run's [`NetSim`] and
    /// returns the connected dense fabric — in `persia train-worker` that
    /// is a [`crate::allreduce::TcpRingMember`] whose ring peers live in
    /// other OS processes; `cluster.n_nn_workers` is the GLOBAL world size
    /// and must match the comm's. The returned output carries loss/AUC
    /// curves only on rank 0 (the ranks share nothing but the wire).
    pub fn run_rank<F: EngineFactory>(
        &self,
        factory: &F,
        make_comm: impl FnOnce(Arc<NetSim>) -> Result<Box<dyn DenseComm>>,
    ) -> Result<TrainOutput> {
        self.validate_cfg()?;
        let ctx = self.setup()?;
        let run_res = self.run_rank_inner(&ctx, factory, make_comm);

        // Stop the applier threads even when the loop errored (a ring peer
        // died, the PS vanished) so the worker process exits cleanly
        // instead of leaking blocked threads.
        Self::stop_appliers(ctx.appliers, ctx.applier_handles);
        let (tracker, gantt, fp, wall_secs, sim_extra) = run_res?;
        Ok(self.build_output(
            &ctx.tier,
            tracker,
            gantt,
            fp,
            wall_secs,
            sim_extra,
            ctx.max_staleness.load(Ordering::Relaxed),
            ctx.put_failures.load(Ordering::Relaxed),
        ))
    }

    /// The fallible part of [`Trainer::run_rank`], split out so the caller
    /// can stop the applier threads on every exit path.
    fn run_rank_inner<F: EngineFactory>(
        &self,
        ctx: &RunCtx,
        factory: &F,
        make_comm: impl FnOnce(Arc<NetSim>) -> Result<Box<dyn DenseComm>>,
    ) -> Result<RankRun> {
        let mut comm = make_comm(ctx.net.clone())?;
        anyhow::ensure!(
            comm.world() == self.cluster.n_nn_workers,
            "dense comm world {} != configured n_nn_workers {} — pass the same \
             --world to every train-worker and use it as the worker count",
            comm.world(),
            self.cluster.n_nn_workers
        );
        let rank = comm.rank();
        let tracker = Mutex::new(Tracker::new());
        let gantt = Mutex::new(GanttTimeline::default());
        let sim_clock = AtomicU64::new(0);
        let final_params = Mutex::new(Vec::new());
        let wall_start = std::time::Instant::now();
        let engine = factory.create(rank)?;
        if let Some(eb) = engine.train_batch() {
            anyhow::ensure!(
                eb == self.train.batch_size,
                "engine batch {eb} != configured batch {}",
                self.train.batch_size
            );
        }
        self.worker_loop(
            rank,
            comm.as_mut(),
            engine,
            &ctx.tier,
            &ctx.appliers,
            &ctx.inflight,
            &ctx.max_staleness,
            ctx.init_params.clone(),
            &tracker,
            &gantt,
            &sim_clock,
            &final_params,
        )?;
        let wall_secs = wall_start.elapsed().as_secs_f64();
        let sim_extra = sim_clock.load(Ordering::Relaxed) as f64 / 1e9;
        Ok((
            tracker.into_inner().unwrap(),
            gantt.into_inner().unwrap(),
            final_params.into_inner().unwrap(),
            wall_secs,
            sim_extra,
        ))
    }

    #[allow(clippy::too_many_arguments)]
    fn worker_loop(
        &self,
        rank: usize,
        comm: &mut dyn DenseComm,
        engine: DenseEngine,
        tier: &Arc<dyn EmbComm>,
        appliers: &[Sender<GradMsg>],
        inflight: &[AtomicI64],
        max_staleness: &AtomicU64,
        mut params: Vec<f32>,
        tracker: &Mutex<Tracker>,
        gantt: &Mutex<GanttTimeline>,
        sim_clock: &AtomicU64,
        final_params: &Mutex<Vec<f32>>,
    ) -> Result<()> {
        let mode = self.train.mode;
        let depth = self.pipeline_depth();
        let mut opt = DenseOptimizer::new(DenseOptimizerKind::Sgd, self.train.lr, params.len());
        // A resumed run starts from the committed epoch's dense state, not
        // the seed-derived init (identical on every rank, like the init).
        if let Some(r) = &self.resume {
            anyhow::ensure!(
                r.params.len() == params.len(),
                "resume manifest has {} dense params, this model needs {}",
                r.params.len(),
                params.len()
            );
            params.copy_from_slice(&r.params);
            opt.restore_state(r.opt_t, &r.opt_m, &r.opt_v)
                .context("restoring dense optimizer state from the resume manifest")?;
        }
        let mut pipeline: VecDeque<Prefetched> = VecDeque::new();
        let mut sim_t = 0.0f64; // this worker's simulated clock
        // Deterministic multi-worker FullSync/FullAsync: serialize every PS
        // touch (and FullAsync's replica gossip) in rank order via the ring
        // token (see `dense_comm::ordered`), so the run is bit-reproducible
        // and provably identical across thread and process deployments.
        let order_ps = self.deterministic && comm.world() > 1;

        // Pull the next embedding-complete batch through the tier seam: the
        // in-process tier draws from the loader and scatter-gathers the PS
        // here; the remote tier issues one NEXT_BATCH RPC to this rank's
        // embedding-worker process, which prefetched it already.
        let prefetch = |step: usize| -> Result<Prefetched> {
            let ew_idx = tier.assign(rank, step);
            let staleness = inflight[ew_idx].load(Ordering::Relaxed).max(0) as u64;
            // `pb.ew` may differ from `ew_idx` under --ew-failover: an
            // elastic tier can reroute the rank mid-call when its assigned
            // worker dies, and the batch reports the worker that actually
            // served it — which is where the gradients must go back to.
            let pb = tier.next_batch(rank, step)?;
            Ok(Prefetched {
                ew: pb.ew,
                sids: pb.sids,
                emb: pb.emb,
                nid: pb.nid,
                labels: pb.labels,
                sim_prep: pb.sim_prep,
                staleness,
            })
        };

        for step in self.start_step..self.train.steps {
            // Keep the pipeline full (async prefetch stands in for the
            // loader+embedding-worker threads running ahead of the GPU).
            while pipeline.len() <= depth {
                let step_ahead = step + pipeline.len();
                let pf = if order_ps {
                    ordered(comm, || prefetch(step_ahead))?
                } else {
                    prefetch(step_ahead)?
                };
                max_staleness.fetch_max(pf.staleness, Ordering::Relaxed);
                pipeline.push_back(pf);
            }
            let pf = pipeline.pop_front().unwrap();

            // Forward + backward (the artifact computes both).
            let t_train0 = std::time::Instant::now();
            let out = engine
                .train_step(&params, &pf.emb, &pf.nid, &pf.labels)
                .context("dense train step")?;
            let t_train = t_train0.elapsed().as_secs_f64();

            // Dense synchronization through the DenseComm seam (in-process
            // mpsc ring or cross-process TCP ring — identical schedule).
            let mut grad = out.grad_flat;
            let t_ar = if mode == TrainMode::FullAsync {
                0.0
            } else {
                let t0 = std::time::Instant::now();
                let sim = comm.all_reduce_mean(&mut grad)?;
                t0.elapsed().as_secs_f64() + sim
            };
            opt.step(&mut params, &grad);

            // FullAsync: replicas drift; re-center periodically with
            // best-effort gossip (shared slots in-process, the peer-to-peer
            // fabric across processes — never a barrier). Deterministic
            // runs use the token-ordered acked variant so the averaging is
            // reproducible and deployment-independent.
            if mode == TrainMode::FullAsync
                && step as u64 % self.gossip_period == self.gossip_period - 1
            {
                if order_ps {
                    comm.replica_average_ordered(&mut params)?;
                } else {
                    comm.replica_average(&mut params)?;
                }
            }

            // Embedding gradient return (Alg. 2 last line -> Alg. 1 backward).
            let t_up = match mode {
                TrainMode::FullSync => {
                    let t0 = std::time::Instant::now();
                    let sim = if order_ps {
                        ordered(comm, || tier.push_grads(pf.ew, &pf.sids, &out.grad_emb))?
                    } else {
                        tier.push_grads(pf.ew, &pf.sids, &out.grad_emb)?
                    };
                    t0.elapsed().as_secs_f64() + sim
                }
                _ if self.deterministic => {
                    // Bit-reproducible variant: apply inline. The pipeline
                    // already pulled the next τ batches, so the staleness
                    // the async appliers would produce is preserved, just
                    // without thread-timing nondeterminism. Cost stays off
                    // the critical path (same overlap accounting as async).
                    // Multi-worker (deterministic FullAsync): the push is
                    // one more token-ordered section, so every PS write
                    // lands in rank order like the prefetches.
                    if order_ps {
                        ordered(comm, || tier.push_grads(pf.ew, &pf.sids, &out.grad_emb))?;
                    } else {
                        tier.push_grads(pf.ew, &pf.sids, &out.grad_emb)?;
                    }
                    0.0
                }
                _ => {
                    inflight[pf.ew].fetch_add(1, Ordering::Relaxed);
                    appliers[pf.ew]
                        .send(GradMsg::Apply { ew: pf.ew, sids: pf.sids, grads: out.grad_emb })
                        .ok();
                    // Hidden from the critical path; cost accounted in sim
                    // math below as overlap-able.
                    0.0
                }
            };

            // --- simulated step time per mode (Fig. 3's overlap algebra) ---
            let t_prep = pf.sim_prep;
            let step_sim = match mode {
                TrainMode::FullSync => t_prep + t_train + t_ar + t_up,
                TrainMode::HybridRaw => {
                    // get/update hidden inside (train + allreduce) window.
                    let hidden = t_prep;
                    t_train + t_ar + (hidden - (t_train + t_ar)).max(0.0)
                }
                TrainMode::Hybrid => {
                    // + allreduce overlapped with the backward 2/3 of train.
                    let exposed_ar = (t_ar - t_train * (2.0 / 3.0)).max(0.0);
                    let window = t_train + exposed_ar;
                    window + (t_prep - window).max(0.0)
                }
                TrainMode::FullAsync => t_train,
            };
            let sim_net_extra = (step_sim - t_train).max(0.0);
            sim_clock.fetch_add((sim_net_extra * 1e9) as u64, Ordering::Relaxed);

            if rank == 0 && self.record_gantt {
                let mut g = gantt.lock().unwrap();
                let t_fwd = t_train / 3.0;
                let t_bwd = t_train - t_fwd;
                match mode {
                    TrainMode::FullSync => {
                        g.push(step as u64, "emb_prep", sim_t, t_prep);
                        g.push(step as u64, "forward", sim_t + t_prep, t_fwd);
                        g.push(step as u64, "backward", sim_t + t_prep + t_fwd, t_bwd);
                        g.push(step as u64, "dense_sync", sim_t + t_prep + t_train, t_ar);
                        g.push(step as u64, "emb_update", sim_t + t_prep + t_train + t_ar, t_up);
                    }
                    TrainMode::HybridRaw => {
                        g.push(step as u64, "emb_prep", sim_t, t_prep);
                        g.push(step as u64, "forward", sim_t, t_fwd);
                        g.push(step as u64, "backward", sim_t + t_fwd, t_bwd);
                        g.push(step as u64, "dense_sync", sim_t + t_train, t_ar);
                        g.push(step as u64, "emb_update", sim_t + t_train * 0.5, t_prep * 0.5);
                    }
                    TrainMode::Hybrid => {
                        g.push(step as u64, "emb_prep", sim_t, t_prep);
                        g.push(step as u64, "forward", sim_t, t_fwd);
                        g.push(step as u64, "backward", sim_t + t_fwd, t_bwd);
                        g.push(step as u64, "dense_sync", sim_t + t_fwd, t_ar);
                        g.push(step as u64, "emb_update", sim_t + t_fwd, t_prep * 0.5);
                    }
                    TrainMode::FullAsync => {
                        g.push(step as u64, "emb_prep", sim_t, t_prep);
                        g.push(step as u64, "forward", sim_t, t_fwd);
                        g.push(step as u64, "backward", sim_t + t_fwd, t_bwd);
                        g.push(step as u64, "emb_update", sim_t + t_fwd, t_prep * 0.5);
                    }
                }
            }
            sim_t += step_sim;

            if rank == 0 {
                let mut tr = tracker.lock().unwrap();
                tr.record_loss(step as u64, out.loss);
                tr.record_phase("emb_prep", (t_prep * 1e9) as u64);
                tr.record_phase("train", (t_train * 1e9) as u64);
                tr.record_phase("dense_sync", (t_ar * 1e9) as u64);
                if self.train.eval_every > 0
                    && (step + 1) % self.train.eval_every == 0
                {
                    let auc_v = self.evaluate(&engine, &params, tier.as_ref())?;
                    tr.record_auc(step as u64 + 1, auc_v);
                }
            }

            // --- live resharding probe at the step boundary ---
            // Runs BEFORE the checkpoint block so a boundary hitting both
            // cadences checkpoints the POST-migration layout: the shard
            // manifests then carry the narrowed/adopted ranges and the new
            // routing epoch, closing the crash window between a reshard
            // commit and its first durable snapshot. Only rank 0 talks to
            // the fleet; in ordered deterministic mode the probe is one
            // more collective ordered section, so the traffic merge and
            // the migration itself happen at a quiesced boundary (no
            // in-flight puts from any rank — the copy window is exact).
            if let Some(rs) = &self.reshard {
                if (step + 1) % rs.every == 0 {
                    let drive = || -> Result<()> {
                        if rank != 0 {
                            return Ok(());
                        }
                        use std::io::Write as _;
                        match tier.maybe_reshard(rs.threshold) {
                            Ok(Some(epoch)) => {
                                // Orchestrators and the chaos drills read
                                // these lines through pipes.
                                println!("RESHARD epoch {epoch} committed");
                                std::io::stdout().flush().ok();
                            }
                            Ok(None) => {}
                            Err(e) => {
                                // Resharding is an optimization: a failed
                                // round must never take training down. The
                                // executor has already aborted the fleet
                                // back to the old layout.
                                println!("RESHARD aborted: {e:#}");
                                std::io::stdout().flush().ok();
                            }
                        }
                        Ok(())
                    };
                    if order_ps {
                        ordered(comm, drive)?;
                    } else if rank == 0 {
                        drive()?;
                    }
                }
            }

            // --- coordinated checkpoint epoch at the step boundary ---
            // Rank 0 is the coordinator (recovery::run_epoch: two-phase PS
            // snapshot, global manifest, LATEST). In ordered deterministic
            // mode the drive is one more COLLECTIVE ordered section: by the
            // time rank 0 holds the token here, every rank's step-`step`
            // put has completed and no rank's next PS touch can start — the
            // epoch is the exact boundary state, which is what makes
            // restore+replay bitwise. In the async modes only rank 0 acts
            // and the boundary is as fuzzy as the modes themselves.
            if let Some(ck) = &self.checkpoint {
                if (step + 1) % ck.every == 0 {
                    let drive = || -> Result<()> {
                        if rank != 0 {
                            return Ok(());
                        }
                        let boundary = (step + 1) as u64;
                        let (opt_t, opt_m, opt_v) = opt.state();
                        let manifest = GlobalManifest {
                            step: boundary,
                            fingerprint: self.config_fingerprint(),
                            world: self.cluster.n_nn_workers,
                            loader_cursors: vec![boundary; self.cluster.n_nn_workers],
                            opt_kind: opt.kind_code(),
                            opt_t,
                            params: params.clone(),
                            opt_m: opt_m.to_vec(),
                            opt_v: opt_v.to_vec(),
                            routing_epoch: tier.routing_epoch(),
                        };
                        run_epoch(&ck.dir, boundary, tier.as_ref(), &manifest)
                            .with_context(|| {
                                format!("checkpoint epoch at step boundary {boundary}")
                            })?;
                        // Orchestrators and the kill drills read this line
                        // through pipes to time their SIGKILLs.
                        println!("CKPT epoch {boundary} committed");
                        use std::io::Write as _;
                        std::io::stdout().flush().ok();
                        Ok(())
                    };
                    if order_ps {
                        ordered(comm, drive)?;
                    } else if rank == 0 {
                        drive()?;
                    }
                }
            }
        }

        // Final eval on worker 0.
        if rank == 0 && self.train.eval_every > 0 {
            let auc_v = self.evaluate(&engine, &params, tier.as_ref())?;
            tracker.lock().unwrap().record_auc(self.train.steps as u64, auc_v);
        }
        *final_params.lock().unwrap() = params;
        Ok(())
    }

    /// Test AUC of the current dense params + live PS state. The pooled
    /// activations come through the embedding tier (worker 0 — in-process
    /// struct or remote process alike); the test batch's NID features and
    /// labels are rebuilt locally from the deterministic held-out stream.
    pub fn evaluate(
        &self,
        engine: &DenseEngine,
        params: &[f32],
        tier: &dyn EmbComm,
    ) -> Result<f64> {
        let batch = self.dataset.test_batch(self.eval_rows);
        let (emb, _) = tier.eval_lookup(self.eval_rows)?;
        anyhow::ensure!(
            emb.len() == batch.len() * self.model.emb_dim(),
            "eval lookup returned {} floats for {} samples",
            emb.len(),
            batch.len()
        );
        let probs = engine.forward(params, &emb, &batch.nid, batch.len())?;
        Ok(auc(&probs, &batch.labels))
    }
}

impl Tracker {
    /// Move the tracker out of a mutex slot (internal helper).
    pub fn take_inner(&mut self) -> Tracker {
        std::mem::take(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        NetModelConfig, OptimizerKind, PartitionPolicy, Pooling,
    };

    fn small_setup(mode: TrainMode, steps: usize, k: usize) -> Trainer {
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 8,
            nid_dim: 4,
            hidden: vec![16, 8],
            ids_per_group: 2,
            pooling: Pooling::Sum,
        };
        let emb_cfg = EmbeddingConfig {
            rows_per_group: 500,
            shard_capacity: 2048,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let cluster = ClusterConfig {
            n_nn_workers: k,
            n_emb_workers: 2,
            net: NetModelConfig::disabled(),
        };
        let train = TrainConfig {
            mode,
            batch_size: 64,
            lr: 0.1,
            staleness_bound: 4,
            steps,
            eval_every: 0,
            seed: 7,
            use_pjrt: false,
            compress: true,
        };
        let dataset = SyntheticDataset::new(&model, 500, 1.05, 7);
        Trainer::new(model, emb_cfg, cluster, train, dataset)
    }

    #[test]
    fn all_modes_run_and_losses_drop() {
        for mode in TrainMode::ALL {
            let trainer = small_setup(mode, 120, 2);
            let out = trainer.run_rust().unwrap();
            let early: f32 = out.tracker.losses[..10].iter().map(|(_, l)| l).sum::<f32>() / 10.0;
            let late = out.tracker.recent_loss(10).unwrap();
            assert!(
                late < early,
                "{mode:?}: loss did not drop ({early} -> {late})"
            );
            assert_eq!(out.report.steps, 120);
        }
    }

    #[test]
    fn sync_mode_has_zero_staleness() {
        let trainer = small_setup(TrainMode::FullSync, 40, 2);
        let out = trainer.run_rust().unwrap();
        assert_eq!(out.report.max_staleness, 0);
    }

    #[test]
    fn hybrid_staleness_is_bounded_by_tau() {
        let trainer = small_setup(TrainMode::Hybrid, 80, 2);
        let tau = trainer.train.staleness_bound as u64;
        let out = trainer.run_rust().unwrap();
        assert!(
            out.report.max_staleness <= tau + 1,
            "staleness {} > tau {}",
            out.report.max_staleness,
            tau
        );
    }

    #[test]
    fn eval_produces_auc_above_chance() {
        let mut trainer = small_setup(TrainMode::Hybrid, 300, 2);
        trainer.train.eval_every = 100;
        trainer.eval_rows = 1024;
        let out = trainer.run_rust().unwrap();
        let final_auc = out.report.final_auc.unwrap();
        assert!(final_auc > 0.55, "auc={final_auc}");
    }

    #[test]
    fn single_worker_runs() {
        let trainer = small_setup(TrainMode::Hybrid, 30, 1);
        let out = trainer.run_rust().unwrap();
        assert_eq!(out.report.samples, 30 * 64);
    }

    #[test]
    fn gantt_recording_captures_phases() {
        let mut trainer = small_setup(TrainMode::FullSync, 5, 1);
        trainer.record_gantt = true;
        trainer.cluster.net = NetModelConfig::paper_like();
        let out = trainer.run_rust().unwrap();
        assert!(!out.gantt.events.is_empty());
        assert!(out.gantt.total_span() > 0.0);
    }

    #[test]
    fn zero_steps_rejected() {
        let mut trainer = small_setup(TrainMode::Hybrid, 10, 1);
        trainer.train.steps = 0;
        assert!(trainer.run_rust().is_err());
    }

    #[test]
    fn deterministic_mode_is_bit_reproducible() {
        // Two deterministic hybrid runs with one NN worker must agree on
        // every recorded loss and the final parameters exactly — the
        // property the remote-PS loopback parity test builds on.
        let run = || {
            let mut t = small_setup(TrainMode::Hybrid, 60, 1);
            t.deterministic = true;
            t.train.eval_every = 30;
            t.run_rust().unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.tracker.losses, b.tracker.losses);
        assert_eq!(a.tracker.aucs, b.tracker.aucs);
        assert_eq!(a.final_params, b.final_params);
    }

    #[test]
    fn deterministic_sync_multiworker_is_bit_reproducible() {
        // With k > 1 the ring token serializes all PS access in rank order,
        // so even a multi-worker FullSync run is exactly reproducible — the
        // property the multi-process train-worker parity test builds on.
        let run = || {
            let mut t = small_setup(TrainMode::FullSync, 40, 2);
            t.deterministic = true;
            t.train.eval_every = 20;
            t.run_rust().unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.tracker.losses, b.tracker.losses);
        assert_eq!(a.tracker.aucs, b.tracker.aucs);
        assert_eq!(a.final_params, b.final_params);
    }

    #[test]
    fn deterministic_multiworker_rejected_for_hybrid_modes() {
        // The hybrid modes' applier threads are inherently unordered;
        // FullSync and FullAsync have token-ordered deterministic variants.
        for mode in [TrainMode::Hybrid, TrainMode::HybridRaw] {
            let mut t = small_setup(mode, 10, 2);
            t.deterministic = true;
            assert!(t.run_rust().is_err(), "{mode:?} must reject deterministic k>1");
        }
    }

    #[test]
    fn deterministic_async_multiworker_is_bit_reproducible() {
        // Token-ordered prefetch, inline ordered push, and ordered acked
        // gossip make even a k > 1 FullAsync run exactly reproducible — the
        // property the cross-process gossip parity test builds on.
        let run = || {
            let mut t = small_setup(TrainMode::FullAsync, 40, 2);
            t.deterministic = true;
            t.gossip_period = 8;
            t.train.eval_every = 20;
            t.run_rust().unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.tracker.losses, b.tracker.losses);
        assert_eq!(a.tracker.aucs, b.tracker.aucs);
        assert_eq!(a.final_params, b.final_params);
    }

    #[test]
    fn zero_gossip_period_rejected() {
        let mut t = small_setup(TrainMode::FullAsync, 5, 1);
        t.gossip_period = 0;
        assert!(t.run_rust().is_err(), "gossip period 0 must be rejected");
    }

    #[test]
    fn run_rank_world_one_matches_run() {
        let make = || {
            let mut t = small_setup(TrainMode::Hybrid, 40, 1);
            t.deterministic = true;
            t.train.eval_every = 40;
            t
        };
        let a = make().run_rust().unwrap();
        let t = make();
        let factory = t.rust_engine_factory();
        let b = t
            .run_rank(&factory, |net| {
                Ok(Box::new(ThreadRing::group(1, net).pop().unwrap()) as Box<dyn DenseComm>)
            })
            .unwrap();
        assert_eq!(a.tracker.losses, b.tracker.losses);
        assert_eq!(a.tracker.aucs, b.tracker.aucs);
        assert_eq!(a.final_params, b.final_params);
    }

    #[test]
    fn run_rank_rejects_world_mismatch() {
        let t = small_setup(TrainMode::FullSync, 5, 2); // configured for 2 workers
        let factory = t.rust_engine_factory();
        let err = t
            .run_rank(&factory, |net| {
                Ok(Box::new(ThreadRing::group(1, net).pop().unwrap()) as Box<dyn DenseComm>)
            })
            .err()
            .expect("world 1 comm vs 2-worker config must fail");
        assert!(format!("{err:#}").contains("world"), "{err:#}");
    }

    #[test]
    fn config_fingerprint_tracks_numeric_knobs() {
        let base = small_setup(TrainMode::Hybrid, 10, 2).config_fingerprint();
        assert_eq!(base, small_setup(TrainMode::Hybrid, 10, 2).config_fingerprint());
        let mut t = small_setup(TrainMode::Hybrid, 10, 2);
        t.train.seed += 1;
        assert_ne!(base, t.config_fingerprint());
        let mut t = small_setup(TrainMode::Hybrid, 10, 2);
        t.train.steps = 11;
        assert_ne!(base, t.config_fingerprint());
        let mut t = small_setup(TrainMode::Hybrid, 10, 2);
        t.cluster.n_nn_workers = 3;
        assert_ne!(base, t.config_fingerprint());
        let mut t = small_setup(TrainMode::Hybrid, 10, 2);
        t.emb_cfg.lr *= 2.0;
        assert_ne!(base, t.config_fingerprint());
        // Dataset distribution knobs are numerics too: a different Zipf
        // skew or label sharpness must change the fingerprint.
        let mut t = small_setup(TrainMode::Hybrid, 10, 2);
        t.dataset = SyntheticDataset::new(&t.model, 500, 1.2, 7);
        assert_ne!(base, t.config_fingerprint());
        let mut t = small_setup(TrainMode::Hybrid, 10, 2);
        t.dataset.signal_scale *= 2.0;
        assert_ne!(base, t.config_fingerprint());
        assert_ne!(base, small_setup(TrainMode::FullSync, 10, 2).config_fingerprint());
    }

    #[test]
    fn explicit_local_tier_matches_default() {
        // Passing a hand-built in-process tier through the emb_comm seam
        // must be identical to letting the trainer build it.
        let steps = 40;
        let make = || {
            let mut t = small_setup(TrainMode::FullSync, steps, 1);
            t.train.eval_every = steps;
            t
        };
        let default_run = make().run_rust().unwrap();
        let mut t = make();
        let net = Arc::new(NetSim::new(t.cluster.net));
        let ps: Arc<dyn PsBackend> = Arc::new(crate::embedding::EmbeddingPs::new(
            &t.emb_cfg,
            t.model.emb_dim_per_group,
            t.train.seed,
        ));
        let tier = Arc::new(LocalEmbTier::new(
            t.dataset.clone(),
            &t.model,
            ps,
            net,
            t.train.compress,
            t.cluster.n_emb_workers,
            t.cluster.n_nn_workers,
            t.train.batch_size,
            t.ew_cache_params(),
        ));
        t.emb_comm = Some(tier);
        let tier_run = t.run_rust().unwrap();
        assert_eq!(default_run.tracker.losses, tier_run.tracker.losses);
        assert_eq!(default_run.tracker.aucs, tier_run.tracker.aucs);
        assert_eq!(default_run.final_params, tier_run.final_params);
    }

    #[test]
    fn tier_worker_count_mismatch_rejected() {
        let mut t = small_setup(TrainMode::FullSync, 5, 1);
        let net = Arc::new(NetSim::new(t.cluster.net));
        let ps: Arc<dyn PsBackend> = Arc::new(crate::embedding::EmbeddingPs::new(
            &t.emb_cfg,
            t.model.emb_dim_per_group,
            t.train.seed,
        ));
        // A 1-worker tier against a cluster config that promises 2.
        let tier = Arc::new(LocalEmbTier::new(
            t.dataset.clone(),
            &t.model,
            ps,
            net,
            t.train.compress,
            1,
            t.cluster.n_nn_workers,
            t.train.batch_size,
            None,
        ));
        t.emb_comm = Some(tier);
        let err = t.run_rust().err().expect("worker-count mismatch must fail");
        assert!(format!("{err:#}").contains("n_emb_workers"), "{err:#}");
    }

    #[test]
    fn explicit_in_process_backend_matches_default() {
        // Passing the in-process PS through the ps_backend override must be
        // identical to letting the trainer build it.
        let steps = 40;
        let make = || {
            let mut t = small_setup(TrainMode::FullSync, steps, 1);
            t.train.eval_every = steps;
            t
        };
        let default_run = make().run_rust().unwrap();
        let mut t = make();
        let ps: Arc<dyn PsBackend> = Arc::new(crate::embedding::EmbeddingPs::new(
            &t.emb_cfg,
            t.model.emb_dim_per_group,
            t.train.seed,
        ));
        t.ps_backend = Some(ps);
        let explicit_run = t.run_rust().unwrap();
        assert_eq!(default_run.tracker.losses, explicit_run.tracker.losses);
        assert_eq!(default_run.tracker.aucs, explicit_run.tracker.aucs);
    }
}
