//! The end-to-end distributed trainer (paper §4.1's data-dispatching
//! procedure, steps (1)-(7)) in all four synchronization modes.
//!
//! Topology (one OS thread per logical node — see DESIGN.md substitutions).
//! The embedding PS sits behind [`PsBackend`]: in-process by default, or a
//! remote TCP server when [`Trainer::ps_backend`] is set to a
//! [`crate::service::RemotePs`] (the TCP service mode in `service/`); all
//! four modes run unchanged against either.
//!
//! ```text
//!   loader(rank r) ──ids──▶ embedding worker ──get/put──▶ embedding PS
//!        │                        ▲      │
//!        └──nid,label──▶ NN worker│◀─emb─┘        NN worker ◀─ring─▶ peers
//!                        (one thread per rank, Alg. 2 + AllReduce)
//! ```
//!
//! Mode semantics (Fig. 3-right):
//! * `FullSync` — all five stages sequential; embedding gradients applied
//!   inline before the next pull (τ = 0).
//! * `HybridRaw` — embedding get/put async with a prefetch pipeline bounded
//!   by τ (`staleness_bound`); dense AllReduce still a separate barrier.
//! * `Hybrid` — + dense AllReduce overlapped with backward (simulated-clock
//!   overlap; the paper does this with Bagua's fused bucket schedule).
//! * `FullAsync` — no dense barrier at all: each worker steps its own
//!   replica and replicas are gossip-averaged only every `ASYNC_SYNC_EVERY`
//!   steps; embedding staleness unbounded (2τ pipeline). Statistical
//!   efficiency drops — exactly the paper's argument for hybrid.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::allreduce::RingGroup;
use crate::comm::NetSim;
use crate::config::{ClusterConfig, EmbeddingConfig, ModelConfig, TrainConfig, TrainMode};
use crate::data::sample::SampleId;
use crate::data::SyntheticDataset;
use crate::dense::{DenseModel, DenseOptimizer, DenseOptimizerKind};
use crate::embedding::EmbeddingPs;
use crate::metrics::{auc, RunReport, Tracker};
use crate::runtime::{ArtifactManifest, DenseEngine, PjRtRuntime};
use crate::service::PsBackend;
use crate::util::Rng;
use crate::worker::{EmbeddingWorker, NnWorker};

use super::gantt::GanttTimeline;

/// How often FullAsync gossip-averages the dense replicas.
const ASYNC_SYNC_EVERY: u64 = 64;

/// Total tries an async gradient applier gives one put. A failed
/// `push_grads` re-buffers its samples, so each retry replays the exact
/// same batch; combined with the remote backend's own reconnect-with-retry
/// this rides out a PS shard process being killed and restarted (§4.2.4).
const PUT_ATTEMPTS: usize = 3;

/// Per-worker dense-engine construction. PJRT executables are not `Send`
/// (the `xla` crate wraps raw PJRT pointers), so every NN-worker thread
/// builds and owns its engine — exactly the paper's topology, where each GPU
/// worker holds its own compiled graph.
pub trait EngineFactory: Sync {
    fn create(&self, rank: usize) -> Result<DenseEngine>;
}

/// Factory for the pure-Rust reference tower.
pub struct RustEngineFactory {
    pub template: DenseModel,
}

impl EngineFactory for RustEngineFactory {
    fn create(&self, _rank: usize) -> Result<DenseEngine> {
        Ok(DenseEngine::rust(self.template.clone()))
    }
}

/// Factory loading the AOT artifacts via a per-thread PJRT CPU client.
pub struct PjrtEngineFactory {
    pub artifacts_dir: std::path::PathBuf,
    pub preset: String,
}

impl EngineFactory for PjrtEngineFactory {
    fn create(&self, _rank: usize) -> Result<DenseEngine> {
        let rt = PjRtRuntime::cpu()?;
        let manifest = ArtifactManifest::load(&self.artifacts_dir)?;
        DenseEngine::pjrt(&rt, &manifest, &self.preset)
    }
}

/// Result of a training run.
pub struct TrainOutput {
    pub report: RunReport,
    /// Worker-0 loss/AUC curves + phase histograms.
    pub tracker: Tracker,
    /// Worker-0 simulated-clock phase timeline (Fig. 3).
    pub gantt: GanttTimeline,
    /// PS imbalance statistic (load-balance ablation).
    pub ps_imbalance: f64,
    /// Worker-0's final dense parameters (flat artifact order).
    pub final_params: Vec<f32>,
}

/// One prefetched, embedding-complete mini-batch.
struct Prefetched {
    ew: usize,
    sids: Vec<SampleId>,
    emb: Vec<f32>,
    nid: Vec<f32>,
    labels: Vec<f32>,
    /// Simulated seconds spent preparing it (PS fetch + transfers).
    sim_prep: f64,
    /// Embedding staleness (pending unapplied grad batches at pull time).
    staleness: u64,
}

/// Work item for the async gradient-applier threads.
enum GradMsg {
    Apply { ew: usize, sids: Vec<SampleId>, grads: Vec<f32> },
    Stop,
}

/// The distributed trainer.
pub struct Trainer {
    pub model: ModelConfig,
    pub emb_cfg: EmbeddingConfig,
    pub cluster: ClusterConfig,
    pub train: TrainConfig,
    pub dataset: SyntheticDataset,
    /// Evaluation batch rows for AUC.
    pub eval_rows: usize,
    /// Record a Gantt timeline on worker 0.
    pub record_gantt: bool,
    /// PS backend override. `None` builds the in-process [`EmbeddingPs`]
    /// from `emb_cfg`; `Some` (a [`crate::service::RemotePs`] or a
    /// multi-process [`crate::service::ShardedRemotePs`]) trains against
    /// it — the TCP service mode.
    pub ps_backend: Option<Arc<dyn PsBackend>>,
    /// Apply embedding gradients inline (single-threaded per worker) instead
    /// of via the async applier threads. The prefetch pipeline still runs τ
    /// batches ahead, so bounded staleness is preserved, but the whole run
    /// becomes bit-reproducible — the loopback service test relies on this
    /// to assert exact in-process vs. remote parity.
    pub deterministic: bool,
}

impl Trainer {
    pub fn new(
        model: ModelConfig,
        emb_cfg: EmbeddingConfig,
        cluster: ClusterConfig,
        train: TrainConfig,
        dataset: SyntheticDataset,
    ) -> Self {
        Self {
            model,
            emb_cfg,
            cluster,
            train,
            dataset,
            eval_rows: 2048,
            record_gantt: false,
            ps_backend: None,
            deterministic: false,
        }
    }

    /// Pipeline depth (bounded staleness τ) for the configured mode.
    fn pipeline_depth(&self) -> usize {
        match self.train.mode {
            TrainMode::FullSync => 0,
            TrainMode::HybridRaw | TrainMode::Hybrid => self.train.staleness_bound,
            TrainMode::FullAsync => self.train.staleness_bound * 2,
        }
    }

    /// Convenience: run with the pure-Rust engine (deterministic template
    /// init derived from the train seed).
    pub fn run_rust(&self) -> Result<TrainOutput> {
        let mut rng = Rng::new(self.train.seed ^ 0xE17);
        let template =
            DenseModel::new(&self.model.dims(), self.model.emb_dim(), self.model.nid_dim, &mut rng);
        self.run(&RustEngineFactory { template })
    }

    /// Run the configured training; `factory` builds each worker's dense
    /// engine (PJRT artifacts or the pure-Rust tower).
    pub fn run<F: EngineFactory>(&self, factory: &F) -> Result<TrainOutput> {
        self.model.validate()?;
        self.emb_cfg.validate()?;
        self.cluster.validate()?;
        self.train.validate()?;
        // Bit-reproducibility is only deliverable single-worker: with k > 1
        // the NN-worker threads still race on the shared PS and AllReduce.
        anyhow::ensure!(
            !self.deterministic || self.cluster.n_nn_workers == 1,
            "deterministic mode requires n_nn_workers == 1 (got {})",
            self.cluster.n_nn_workers
        );

        let net = Arc::new(NetSim::new(self.cluster.net));
        let backend: Arc<dyn PsBackend> = match &self.ps_backend {
            Some(backend) => backend.clone(),
            None => Arc::new(EmbeddingPs::new(
                &self.emb_cfg,
                self.model.emb_dim_per_group,
                self.train.seed,
            )),
        };
        anyhow::ensure!(
            backend.dim() == self.model.emb_dim_per_group,
            "PS backend dim {} != model group dim {}",
            backend.dim(),
            self.model.emb_dim_per_group
        );
        // A remote PS built from different flags than this trainer would
        // silently train different numerics; fail the handshake instead.
        backend.check_compat(&self.emb_cfg, self.train.seed)?;
        let emb_workers: Vec<Arc<EmbeddingWorker>> = (0..self.cluster.n_emb_workers)
            .map(|r| {
                Arc::new(EmbeddingWorker::new(
                    r as u8,
                    backend.clone(),
                    &self.model,
                    net.clone(),
                    self.train.compress,
                ))
            })
            .collect();

        // Async gradient appliers: one thread per embedding worker; the
        // in-flight counter per worker is the measured staleness.
        let inflight: Arc<Vec<AtomicI64>> =
            Arc::new((0..emb_workers.len()).map(|_| AtomicI64::new(0)).collect());
        let max_staleness = Arc::new(AtomicU64::new(0));
        let put_failures = Arc::new(AtomicU64::new(0));
        let mut applier_handles = Vec::with_capacity(emb_workers.len());
        let appliers: Vec<Sender<GradMsg>> = emb_workers
            .iter()
            .map(|ew| {
                let ew = ew.clone();
                let inflight = inflight.clone();
                let put_failures = put_failures.clone();
                let (tx, rx) = channel::<GradMsg>();
                let handle = std::thread::Builder::new()
                    .name(format!("grad-applier-{}", ew.rank()))
                    .spawn(move || {
                        while let Ok(msg) = rx.recv() {
                            match msg {
                                GradMsg::Apply { ew: idx, sids, grads } => {
                                    // A failed push re-buffers its samples,
                                    // so the same batch can be replayed —
                                    // retry a bounded number of times (a
                                    // killed PS shard may be restarting).
                                    // Losing a put after that is tolerated
                                    // (§4.2.4), but never silently: count it
                                    // and surface the first failure.
                                    let mut res = ew.push_grads(&sids, &grads);
                                    for _ in 1..PUT_ATTEMPTS {
                                        if res.is_ok() {
                                            break;
                                        }
                                        res = ew.push_grads(&sids, &grads);
                                    }
                                    if let Err(e) = res {
                                        // Give the batch up for good: drop
                                        // the re-buffered samples so a dead
                                        // shard doesn't grow the buffer
                                        // without bound (§4.2.4 tolerates
                                        // the lost update, not the leak).
                                        ew.discard(&sids);
                                        if put_failures.fetch_add(1, Ordering::Relaxed) == 0 {
                                            eprintln!(
                                                "grad applier: put failed \
                                                 ({PUT_ATTEMPTS} attempts): {e:#}"
                                            );
                                        }
                                    }
                                    inflight[idx].fetch_sub(1, Ordering::Relaxed);
                                }
                                GradMsg::Stop => return,
                            }
                        }
                    })
                    .expect("spawn applier");
                applier_handles.push(handle);
                tx
            })
            .collect();

        // Identical dense init on every worker (paper: replicas start equal).
        let mut init_rng = Rng::new(self.train.seed ^ 0xD15E);
        let dims = self.model.dims();
        let init_model =
            DenseModel::new(&dims, self.model.emb_dim(), self.model.nid_dim, &mut init_rng);
        let init_params = init_model.params_flat();

        let k = self.cluster.n_nn_workers;
        let ring = RingGroup::new(k, net.clone());
        // FullAsync gossip: replicas post params to a shared slot array.
        let gossip: Arc<Vec<Mutex<Vec<f32>>>> =
            Arc::new((0..k).map(|_| Mutex::new(Vec::new())).collect());

        let trackers: Vec<Mutex<Tracker>> = (0..k).map(|_| Mutex::new(Tracker::new())).collect();
        let gantts: Vec<Mutex<GanttTimeline>> =
            (0..k).map(|_| Mutex::new(GanttTimeline::default())).collect();
        let sim_clocks: Vec<AtomicU64> = (0..k).map(|_| AtomicU64::new(0)).collect();
        let wall_start = std::time::Instant::now();
        let final_params: Vec<Mutex<Vec<f32>>> = (0..k).map(|_| Mutex::new(Vec::new())).collect();

        let out: Result<Vec<()>> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (rank, member) in ring.into_iter().enumerate() {
                let emb_workers = &emb_workers;
                // mpsc Senders are Send but not Sync: clone per thread.
                let appliers: Vec<Sender<GradMsg>> = appliers.clone();
                let inflight = inflight.clone();
                let max_staleness = max_staleness.clone();
                let init_params = init_params.clone();
                let gossip = gossip.clone();
                let trackers = &trackers;
                let gantts = &gantts;
                let sim_clocks = &sim_clocks;
                let final_params = &final_params;
                handles.push(scope.spawn(move || -> Result<()> {
                    let engine = factory.create(rank)?;
                    if let Some(eb) = engine.train_batch() {
                        anyhow::ensure!(
                            eb == self.train.batch_size,
                            "engine batch {eb} != configured batch {}",
                            self.train.batch_size
                        );
                    }
                    self.worker_loop(
                        rank,
                        member,
                        engine,
                        emb_workers,
                        &appliers,
                        &inflight,
                        &max_staleness,
                        init_params,
                        &gossip,
                        &trackers[rank],
                        &gantts[rank],
                        &sim_clocks[rank],
                        &final_params[rank],
                    )
                }));
            }
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        out?;

        // Drain the appliers (queued puts apply in order before Stop) so the
        // failure count below is complete and no thread outlives the run.
        for tx in &appliers {
            let _ = tx.send(GradMsg::Stop);
        }
        drop(appliers);
        for handle in applier_handles {
            let _ = handle.join();
        }

        let wall_secs = wall_start.elapsed().as_secs_f64();
        let sim_extra = sim_clocks
            .iter()
            .map(|c| c.load(Ordering::Relaxed) as f64 / 1e9)
            .fold(0.0, f64::max);
        let tracker = trackers[0].lock().unwrap();
        let samples = (self.train.steps * self.train.batch_size * k) as u64;
        // Simulated time = real compute wall time + injected network time
        // (which threads did not actually sleep through).
        let sim_secs = wall_secs + sim_extra;
        let report = RunReport {
            mode: self.train.mode.name().to_string(),
            steps: self.train.steps as u64,
            samples,
            wall_secs,
            sim_secs,
            final_loss: tracker.recent_loss(20).unwrap_or(f32::NAN),
            final_auc: tracker.final_auc(),
            samples_per_sec: samples as f64 / sim_secs.max(1e-9),
            max_staleness: max_staleness.load(Ordering::Relaxed),
            grad_put_failures: put_failures.load(Ordering::Relaxed),
        };
        drop(tracker);
        let tracker = trackers[0].lock().unwrap().take_inner();
        let gantt = gantts[0].lock().unwrap().clone();
        let fp = std::mem::take(&mut *final_params[0].lock().unwrap());
        let ps_imbalance = backend.stats().map(|s| s.imbalance).unwrap_or(f64::NAN);
        Ok(TrainOutput { report, tracker, gantt, ps_imbalance, final_params: fp })
    }

    #[allow(clippy::too_many_arguments)]
    fn worker_loop(
        &self,
        rank: usize,
        member: crate::allreduce::ring::RingMember,
        engine: DenseEngine,
        emb_workers: &[Arc<EmbeddingWorker>],
        appliers: &[Sender<GradMsg>],
        inflight: &[AtomicI64],
        max_staleness: &AtomicU64,
        mut params: Vec<f32>,
        gossip: &[Mutex<Vec<f32>>],
        tracker: &Mutex<Tracker>,
        gantt: &Mutex<GanttTimeline>,
        sim_clock: &AtomicU64,
        final_params: &Mutex<Vec<f32>>,
    ) -> Result<()> {
        let mode = self.train.mode;
        let b = self.train.batch_size;
        let depth = self.pipeline_depth();
        let mut opt = DenseOptimizer::new(DenseOptimizerKind::Sgd, self.train.lr, params.len());
        let mut rng = self.dataset.train_rng(rank as u64);
        let nn = NnWorker::new(rank, self.model.nid_dim);
        let mut pipeline: VecDeque<Prefetched> = VecDeque::new();
        let mut sim_t = 0.0f64; // this worker's simulated clock
        let n_ew = emb_workers.len();

        let prefetch = |rng: &mut Rng, step: usize| -> Result<Prefetched> {
            let batch = self.dataset.batch(rng, b);
            let ew_idx = (rank + step) % n_ew;
            let ew = &emb_workers[ew_idx];
            let t0 = std::time::Instant::now();
            let sids = ew.register(batch.ids);
            nn.receive_batch(&sids, &batch.nid, &batch.labels);
            let staleness = inflight[ew_idx].load(Ordering::Relaxed).max(0) as u64;
            let (emb, sim) = ew.pull(&sids)?;
            let (nid, labels) = nn.take(&sids)?;
            Ok(Prefetched {
                ew: ew_idx,
                sids,
                emb,
                nid,
                labels,
                sim_prep: sim + t0.elapsed().as_secs_f64(),
                staleness,
            })
        };

        for step in 0..self.train.steps {
            // Keep the pipeline full (async prefetch stands in for the
            // loader+embedding-worker threads running ahead of the GPU).
            while pipeline.len() <= depth {
                let pf = prefetch(&mut rng, step + pipeline.len())?;
                max_staleness.fetch_max(pf.staleness, Ordering::Relaxed);
                pipeline.push_back(pf);
            }
            let pf = pipeline.pop_front().unwrap();

            // Forward + backward (the artifact computes both).
            let t_train0 = std::time::Instant::now();
            let out = engine
                .train_step(&params, &pf.emb, &pf.nid, &pf.labels)
                .context("dense train step")?;
            let t_train = t_train0.elapsed().as_secs_f64();

            // Dense synchronization.
            let mut grad = out.grad_flat;
            let t_ar = if mode == TrainMode::FullAsync {
                0.0
            } else {
                let t0 = std::time::Instant::now();
                let sim = member.all_reduce_mean(&mut grad);
                t0.elapsed().as_secs_f64() + sim
            };
            opt.step(&mut params, &grad);

            // FullAsync: replicas drift; gossip-average periodically.
            if mode == TrainMode::FullAsync {
                if step as u64 % ASYNC_SYNC_EVERY == ASYNC_SYNC_EVERY - 1 {
                    *gossip[rank].lock().unwrap() = params.clone();
                    // Best-effort average over whatever replicas have posted.
                    let mut acc = params.clone();
                    let mut n = 1.0f32;
                    for (i, slot) in gossip.iter().enumerate() {
                        if i == rank {
                            continue;
                        }
                        let other = slot.lock().unwrap();
                        if other.len() == acc.len() {
                            for (a, o) in acc.iter_mut().zip(other.iter()) {
                                *a += o;
                            }
                            n += 1.0;
                        }
                    }
                    let inv = 1.0 / n;
                    for a in acc.iter_mut() {
                        *a *= inv;
                    }
                    params = acc;
                }
            }

            // Embedding gradient return (Alg. 2 last line -> Alg. 1 backward).
            let t_up = match mode {
                TrainMode::FullSync => {
                    let t0 = std::time::Instant::now();
                    let sim = emb_workers[pf.ew].push_grads(&pf.sids, &out.grad_emb)?;
                    t0.elapsed().as_secs_f64() + sim
                }
                _ if self.deterministic => {
                    // Bit-reproducible variant: apply inline. The pipeline
                    // already pulled the next τ batches, so the staleness
                    // the async appliers would produce is preserved, just
                    // without thread-timing nondeterminism. Cost stays off
                    // the critical path (same overlap accounting as async).
                    emb_workers[pf.ew].push_grads(&pf.sids, &out.grad_emb)?;
                    0.0
                }
                _ => {
                    inflight[pf.ew].fetch_add(1, Ordering::Relaxed);
                    appliers[pf.ew]
                        .send(GradMsg::Apply { ew: pf.ew, sids: pf.sids, grads: out.grad_emb })
                        .ok();
                    // Hidden from the critical path; cost accounted in sim
                    // math below as overlap-able.
                    0.0
                }
            };

            // --- simulated step time per mode (Fig. 3's overlap algebra) ---
            let t_prep = pf.sim_prep;
            let step_sim = match mode {
                TrainMode::FullSync => t_prep + t_train + t_ar + t_up,
                TrainMode::HybridRaw => {
                    // get/update hidden inside (train + allreduce) window.
                    let hidden = t_prep;
                    t_train + t_ar + (hidden - (t_train + t_ar)).max(0.0)
                }
                TrainMode::Hybrid => {
                    // + allreduce overlapped with the backward 2/3 of train.
                    let exposed_ar = (t_ar - t_train * (2.0 / 3.0)).max(0.0);
                    let window = t_train + exposed_ar;
                    window + (t_prep - window).max(0.0)
                }
                TrainMode::FullAsync => t_train,
            };
            let sim_net_extra = (step_sim - t_train).max(0.0);
            sim_clock.fetch_add((sim_net_extra * 1e9) as u64, Ordering::Relaxed);

            if rank == 0 && self.record_gantt {
                let mut g = gantt.lock().unwrap();
                let t_fwd = t_train / 3.0;
                let t_bwd = t_train - t_fwd;
                match mode {
                    TrainMode::FullSync => {
                        g.push(step as u64, "emb_prep", sim_t, t_prep);
                        g.push(step as u64, "forward", sim_t + t_prep, t_fwd);
                        g.push(step as u64, "backward", sim_t + t_prep + t_fwd, t_bwd);
                        g.push(step as u64, "dense_sync", sim_t + t_prep + t_train, t_ar);
                        g.push(step as u64, "emb_update", sim_t + t_prep + t_train + t_ar, t_up);
                    }
                    TrainMode::HybridRaw => {
                        g.push(step as u64, "emb_prep", sim_t, t_prep);
                        g.push(step as u64, "forward", sim_t, t_fwd);
                        g.push(step as u64, "backward", sim_t + t_fwd, t_bwd);
                        g.push(step as u64, "dense_sync", sim_t + t_train, t_ar);
                        g.push(step as u64, "emb_update", sim_t + t_train * 0.5, t_prep * 0.5);
                    }
                    TrainMode::Hybrid => {
                        g.push(step as u64, "emb_prep", sim_t, t_prep);
                        g.push(step as u64, "forward", sim_t, t_fwd);
                        g.push(step as u64, "backward", sim_t + t_fwd, t_bwd);
                        g.push(step as u64, "dense_sync", sim_t + t_fwd, t_ar);
                        g.push(step as u64, "emb_update", sim_t + t_fwd, t_prep * 0.5);
                    }
                    TrainMode::FullAsync => {
                        g.push(step as u64, "emb_prep", sim_t, t_prep);
                        g.push(step as u64, "forward", sim_t, t_fwd);
                        g.push(step as u64, "backward", sim_t + t_fwd, t_bwd);
                        g.push(step as u64, "emb_update", sim_t + t_fwd, t_prep * 0.5);
                    }
                }
            }
            sim_t += step_sim;

            if rank == 0 {
                let mut tr = tracker.lock().unwrap();
                tr.record_loss(step as u64, out.loss);
                tr.record_phase("emb_prep", (t_prep * 1e9) as u64);
                tr.record_phase("train", (t_train * 1e9) as u64);
                tr.record_phase("dense_sync", (t_ar * 1e9) as u64);
                if self.train.eval_every > 0
                    && (step + 1) % self.train.eval_every == 0
                {
                    let auc_v = self.evaluate(&engine, &params, &emb_workers[0])?;
                    tr.record_auc(step as u64 + 1, auc_v);
                }
            }
        }

        // Final eval on worker 0.
        if rank == 0 && self.train.eval_every > 0 {
            let auc_v = self.evaluate(&engine, &params, &emb_workers[0])?;
            tracker.lock().unwrap().record_auc(self.train.steps as u64, auc_v);
        }
        *final_params.lock().unwrap() = params;
        Ok(())
    }

    /// Test AUC of the current dense params + live PS state.
    pub fn evaluate(
        &self,
        engine: &DenseEngine,
        params: &[f32],
        ew: &EmbeddingWorker,
    ) -> Result<f64> {
        let batch = self.dataset.test_batch(self.eval_rows);
        let (emb, _) = ew.lookup_direct(&batch)?;
        let probs = engine.forward(params, &emb, &batch.nid, batch.len())?;
        Ok(auc(&probs, &batch.labels))
    }
}

impl Tracker {
    /// Move the tracker out of a mutex slot (internal helper).
    pub fn take_inner(&mut self) -> Tracker {
        std::mem::take(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        NetModelConfig, OptimizerKind, PartitionPolicy, Pooling,
    };

    fn small_setup(mode: TrainMode, steps: usize, k: usize) -> Trainer {
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 8,
            nid_dim: 4,
            hidden: vec![16, 8],
            ids_per_group: 2,
            pooling: Pooling::Sum,
        };
        let emb_cfg = EmbeddingConfig {
            rows_per_group: 500,
            shard_capacity: 2048,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let cluster = ClusterConfig {
            n_nn_workers: k,
            n_emb_workers: 2,
            net: NetModelConfig::disabled(),
        };
        let train = TrainConfig {
            mode,
            batch_size: 64,
            lr: 0.1,
            staleness_bound: 4,
            steps,
            eval_every: 0,
            seed: 7,
            use_pjrt: false,
            compress: true,
        };
        let dataset = SyntheticDataset::new(&model, 500, 1.05, 7);
        Trainer::new(model, emb_cfg, cluster, train, dataset)
    }

    #[test]
    fn all_modes_run_and_losses_drop() {
        for mode in TrainMode::ALL {
            let trainer = small_setup(mode, 120, 2);
            let out = trainer.run_rust().unwrap();
            let early: f32 = out.tracker.losses[..10].iter().map(|(_, l)| l).sum::<f32>() / 10.0;
            let late = out.tracker.recent_loss(10).unwrap();
            assert!(
                late < early,
                "{mode:?}: loss did not drop ({early} -> {late})"
            );
            assert_eq!(out.report.steps, 120);
        }
    }

    #[test]
    fn sync_mode_has_zero_staleness() {
        let trainer = small_setup(TrainMode::FullSync, 40, 2);
        let out = trainer.run_rust().unwrap();
        assert_eq!(out.report.max_staleness, 0);
    }

    #[test]
    fn hybrid_staleness_is_bounded_by_tau() {
        let trainer = small_setup(TrainMode::Hybrid, 80, 2);
        let tau = trainer.train.staleness_bound as u64;
        let out = trainer.run_rust().unwrap();
        assert!(
            out.report.max_staleness <= tau + 1,
            "staleness {} > tau {}",
            out.report.max_staleness,
            tau
        );
    }

    #[test]
    fn eval_produces_auc_above_chance() {
        let mut trainer = small_setup(TrainMode::Hybrid, 300, 2);
        trainer.train.eval_every = 100;
        trainer.eval_rows = 1024;
        let out = trainer.run_rust().unwrap();
        let final_auc = out.report.final_auc.unwrap();
        assert!(final_auc > 0.55, "auc={final_auc}");
    }

    #[test]
    fn single_worker_runs() {
        let trainer = small_setup(TrainMode::Hybrid, 30, 1);
        let out = trainer.run_rust().unwrap();
        assert_eq!(out.report.samples, 30 * 64);
    }

    #[test]
    fn gantt_recording_captures_phases() {
        let mut trainer = small_setup(TrainMode::FullSync, 5, 1);
        trainer.record_gantt = true;
        trainer.cluster.net = NetModelConfig::paper_like();
        let out = trainer.run_rust().unwrap();
        assert!(!out.gantt.events.is_empty());
        assert!(out.gantt.total_span() > 0.0);
    }

    #[test]
    fn zero_steps_rejected() {
        let mut trainer = small_setup(TrainMode::Hybrid, 10, 1);
        trainer.train.steps = 0;
        assert!(trainer.run_rust().is_err());
    }

    #[test]
    fn deterministic_mode_is_bit_reproducible() {
        // Two deterministic hybrid runs with one NN worker must agree on
        // every recorded loss and the final parameters exactly — the
        // property the remote-PS loopback parity test builds on.
        let run = || {
            let mut t = small_setup(TrainMode::Hybrid, 60, 1);
            t.deterministic = true;
            t.train.eval_every = 30;
            t.run_rust().unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.tracker.losses, b.tracker.losses);
        assert_eq!(a.tracker.aucs, b.tracker.aucs);
        assert_eq!(a.final_params, b.final_params);
    }

    #[test]
    fn explicit_in_process_backend_matches_default() {
        // Passing the in-process PS through the ps_backend override must be
        // identical to letting the trainer build it.
        let steps = 40;
        let make = || {
            let mut t = small_setup(TrainMode::FullSync, steps, 1);
            t.train.eval_every = steps;
            t
        };
        let default_run = make().run_rust().unwrap();
        let mut t = make();
        let ps: Arc<dyn PsBackend> = Arc::new(crate::embedding::EmbeddingPs::new(
            &t.emb_cfg,
            t.model.emb_dim_per_group,
            t.train.seed,
        ));
        t.ps_backend = Some(ps);
        let explicit_run = t.run_rust().unwrap();
        assert_eq!(default_run.tracker.losses, explicit_run.tracker.losses);
        assert_eq!(default_run.tracker.aucs, explicit_run.tracker.aucs);
    }
}
