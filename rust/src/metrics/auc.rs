//! Exact ROC-AUC via rank statistics.
//!
//! AUC is the paper's convergence metric for every benchmark (Fig. 6/7,
//! Table 2). Computed exactly: sort by score, Mann-Whitney U with midrank
//! tie handling.

/// Exact ROC-AUC of `scores` against binary `labels` (1.0 = positive).
/// Returns 0.5 for degenerate inputs (single class or empty).
///
/// Returns `f64::NAN` if any score is NaN: ranking against NaN is
/// undefined, and the previous `partial_cmp().unwrap_or(Equal)` fallback
/// silently produced an arbitrary (sort-order-dependent) AUC instead — a
/// diverged model would report a plausible-looking number. NaN propagates
/// visibly to the report, where it belongs.
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    if scores.iter().any(|s| s.is_nan()) {
        return f64::NAN;
    }
    let n = scores.len();
    let n_pos = labels.iter().filter(|&&y| y > 0.5).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }

    let mut idx: Vec<usize> = (0..n).collect();
    // total_cmp is a real total order; NaN was excluded above, so this is
    // the plain float order (and the `==` tie grouping below is sound).
    idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));

    // Sum of midranks of positives.
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        // Ranks i+1 ..= j+1 share midrank.
        let midrank = (i + 1 + j + 1) as f64 / 2.0;
        for &k in &idx[i..=j] {
            if labels[k] > 0.5 {
                rank_sum_pos += midrank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos as f64) * (n_pos as f64 + 1.0) / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn perfect_separation_is_one() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(auc(&scores, &labels), 1.0);
    }

    #[test]
    fn inverted_is_zero() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(auc(&scores, &labels), 0.0);
    }

    #[test]
    fn random_scores_near_half() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let labels: Vec<f32> = (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.02, "auc={a}");
    }

    #[test]
    fn ties_get_midrank() {
        // All scores equal -> AUC exactly 0.5.
        let scores = [0.5; 10];
        let labels = [1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        assert_eq!(auc(&scores, &labels), 0.5);
    }

    #[test]
    fn degenerate_single_class() {
        assert_eq!(auc(&[0.1, 0.9], &[1.0, 1.0]), 0.5);
        assert_eq!(auc(&[], &[]), 0.5);
    }

    #[test]
    fn nan_scores_yield_nan_not_garbage() {
        // A NaN anywhere makes ranking undefined: report NaN, don't pick an
        // ordering-dependent answer.
        assert!(auc(&[0.1, f32::NAN, 0.9], &[0.0, 1.0, 1.0]).is_nan());
        assert!(auc(&[f32::NAN], &[1.0]).is_nan());
        // NaN wins over the degenerate-input fallback too.
        assert!(auc(&[f32::NAN, f32::NAN], &[1.0, 1.0]).is_nan());
        // Infinities are orderable and fine.
        let a = auc(&[f32::NEG_INFINITY, 0.0, f32::INFINITY], &[0.0, 0.0, 1.0]);
        assert_eq!(a, 1.0);
    }

    #[test]
    fn matches_brute_force_pair_count() {
        let mut rng = Rng::new(2);
        let n = 200;
        let scores: Vec<f32> = (0..n).map(|_| (rng.below(50) as f32) / 10.0).collect();
        let labels: Vec<f32> = (0..n).map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect();
        // Brute force: P(score_pos > score_neg) + 0.5 P(==).
        let mut wins = 0.0f64;
        let mut total = 0.0f64;
        for i in 0..n {
            if labels[i] < 0.5 {
                continue;
            }
            for j in 0..n {
                if labels[j] > 0.5 {
                    continue;
                }
                total += 1.0;
                if scores[i] > scores[j] {
                    wins += 1.0;
                } else if scores[i] == scores[j] {
                    wins += 0.5;
                }
            }
        }
        let brute = wins / total;
        let fast = auc(&scores, &labels);
        assert!((brute - fast).abs() < 1e-9, "brute={brute} fast={fast}");
    }
}
