//! Run-level metric tracking: loss EMA, throughput, phase timings.

use std::time::Instant;

use crate::util::Histogram;

/// Samples/sec counter.
pub struct Throughput {
    start: Instant,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Self { start: Instant::now(), items: 0 }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    pub fn per_sec(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.items as f64 / secs
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Training-run tracker: losses per step, AUC evals, phase histograms.
#[derive(Default)]
pub struct Tracker {
    pub losses: Vec<(u64, f32)>,
    pub aucs: Vec<(u64, f64)>,
    /// Nanosecond histograms per named phase (emb_get, fwd_bwd, allreduce...).
    phases: Vec<(String, Histogram)>,
}

impl Tracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_loss(&mut self, step: u64, loss: f32) {
        self.losses.push((step, loss));
    }

    pub fn record_auc(&mut self, step: u64, auc: f64) {
        self.aucs.push((step, auc));
    }

    pub fn record_phase(&mut self, phase: &str, ns: u64) {
        if let Some((_, h)) = self.phases.iter_mut().find(|(n, _)| n == phase) {
            h.record(ns);
        } else {
            let mut h = Histogram::new();
            h.record(ns);
            self.phases.push((phase.to_string(), h));
        }
    }

    pub fn phase(&self, name: &str) -> Option<&Histogram> {
        self.phases.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    pub fn phases(&self) -> &[(String, Histogram)] {
        &self.phases
    }

    /// Mean of the last `k` recorded losses.
    pub fn recent_loss(&self, k: usize) -> Option<f32> {
        if self.losses.is_empty() {
            return None;
        }
        let tail = &self.losses[self.losses.len().saturating_sub(k)..];
        Some(tail.iter().map(|(_, l)| l).sum::<f32>() / tail.len() as f32)
    }

    pub fn final_auc(&self) -> Option<f64> {
        self.aucs.last().map(|(_, a)| *a)
    }

    /// First step at which AUC reached `target` (for time-to-AUC, Fig. 6).
    pub fn steps_to_auc(&self, target: f64) -> Option<u64> {
        self.aucs.iter().find(|(_, a)| *a >= target).map(|(s, _)| *s)
    }
}

/// Final report of a training run, consumed by benches and examples.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub mode: String,
    pub steps: u64,
    pub samples: u64,
    pub wall_secs: f64,
    /// Simulated seconds (wallclock + injected network model time).
    pub sim_secs: f64,
    pub final_loss: f32,
    pub final_auc: Option<f64>,
    pub samples_per_sec: f64,
    /// Max observed embedding staleness (Theorem 1's τ).
    pub max_staleness: u64,
    /// Embedding gradient puts that failed in the async appliers. Occasional
    /// losses are tolerated (§4.2.4), but a nonzero count against a remote
    /// PS usually means the connection died mid-run — check it.
    pub grad_put_failures: u64,
}

impl RunReport {
    pub fn print_row(&self) {
        println!(
            "{:<12} steps={:<6} samples={:<8} wall={:>7.2}s sim={:>8.2}s loss={:<8.4} auc={} thpt={:.0}/s tau={}{}",
            self.mode,
            self.steps,
            self.samples,
            self.wall_secs,
            self.sim_secs,
            self.final_loss,
            self.final_auc.map(|a| format!("{a:.4}")).unwrap_or_else(|| "-".into()),
            self.samples_per_sec,
            self.max_staleness,
            if self.grad_put_failures > 0 {
                format!(" LOST-PUTS={}", self.grad_put_failures)
            } else {
                String::new()
            }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(100);
        t.add(50);
        assert_eq!(t.items(), 150);
        assert!(t.per_sec() > 0.0);
    }

    #[test]
    fn tracker_records_and_queries() {
        let mut t = Tracker::new();
        t.record_loss(1, 0.9);
        t.record_loss(2, 0.7);
        t.record_loss(3, 0.5);
        t.record_auc(2, 0.55);
        t.record_auc(3, 0.72);
        assert_eq!(t.recent_loss(2), Some(0.6));
        assert_eq!(t.final_auc(), Some(0.72));
        assert_eq!(t.steps_to_auc(0.7), Some(3));
        assert_eq!(t.steps_to_auc(0.9), None);
    }

    #[test]
    fn phases_accumulate() {
        let mut t = Tracker::new();
        t.record_phase("fwd", 100);
        t.record_phase("fwd", 200);
        t.record_phase("bwd", 300);
        assert_eq!(t.phase("fwd").unwrap().count(), 2);
        assert_eq!(t.phase("bwd").unwrap().count(), 1);
        assert!(t.phase("nope").is_none());
    }
}
