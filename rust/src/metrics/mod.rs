//! Evaluation + run metrics: AUC, loss tracking, throughput counters.

pub mod auc;
pub mod tracker;

pub use auc::auc;
pub use tracker::{RunReport, Throughput, Tracker};
