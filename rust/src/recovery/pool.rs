//! The self-healing RPC connection pool every TCP client rides on.
//!
//! [`RemotePs`](crate::service::RemotePs) and
//! [`RemoteEmbeddingWorker`](crate::service::RemoteEmbeddingWorker) used to
//! carry two private copies of the same machinery: a vector of mutex-guarded
//! connections handed out round-robin, a "drop the connection and re-dial
//! with backoff" loop, and a re-handshake that insists the server is still
//! the one originally connected. [`ReconnectPool`] is that machinery,
//! extracted once; what differs per protocol — how to dial, handshake, and
//! verify a fresh connection — lives behind the [`Redial`] trait.
//!
//! Each slot holds a [`PipelinedClient`]: many sequence-tagged requests in
//! flight per connection, demuxed by correlation id. Callers clone the
//! client *out* of the slot and do their I/O with the slot lock released,
//! so one slow RPC never serializes the other threads sharing the slot —
//! and [`ReconnectPool::call_async`] exposes the pipelining directly for
//! scatter-gather clients. Slots recover from mutex poisoning
//! ([`lock_unpoisoned`]): a thread that panics mid-pool must not take every
//! other trainer thread down with it.
//!
//! A redial is also where §4.2.4 recovery hooks in: the PS client's
//! [`Redial`] impl notices (via the INFO boot nonce) that the server is a
//! *new process* restored from a checkpoint epoch and replays its
//! [`PutReplayLog`](super::PutReplayLog) over the fresh connection before
//! the pool serves any other traffic on it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{ensure, Context, Result};

use crate::comm::rpc::{PendingReply, PipelinedClient};
use crate::util::lock_unpoisoned;

use super::retry::RetryPolicy;

/// One pooled RPC connection: a pipelined client, cheap to clone out of
/// its slot (clones share the connection, window, and completion map).
pub type PooledConn = PipelinedClient;

/// The terminal error of [`ReconnectPool::call`]: every attempt of the
/// retry budget failed, so the endpoint is considered *down*, not flaky.
/// Callers that react to dead endpoints (e.g. the embedding tier's rank
/// failover) detect it with [`Unreachable::in_chain`] — this struct is the
/// single source of the message, so detection and rendering cannot drift
/// apart.
#[derive(Clone, Debug)]
pub struct Unreachable {
    /// [`Redial::describe`] of the endpoint that stayed down.
    pub what: String,
    /// Reconnect attempts that were exhausted.
    pub attempts: u32,
}

impl Unreachable {
    /// Whether `err` carries a pool's exhausted-retries terminal context at
    /// any chain layer (the layer is rendered by [`Unreachable`]'s
    /// `Display`, so the patterns here match by construction).
    pub fn in_chain(err: &anyhow::Error) -> bool {
        err.chain()
            .any(|layer| layer.contains(" unreachable after ") && layer.contains("reconnect attempt"))
    }
}

impl std::fmt::Display for Unreachable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} unreachable after {} reconnect attempt(s)", self.what, self.attempts)
    }
}

/// Dial + handshake policy of one pooled endpoint.
///
/// `redial` is called both to fill the pool initially and to replace every
/// connection that died, so it must be safe to run concurrently from
/// multiple pool slots (protocol-level recovery state, like a replay log,
/// guards itself).
pub trait Redial: Send + Sync {
    /// Dial a fresh connection, run the protocol handshake, and verify the
    /// server is (still) the endpoint originally connected — a process
    /// restarted with different flags must not silently rejoin.
    fn redial(&self) -> Result<PooledConn>;

    /// Human-readable endpoint description for error contexts
    /// (e.g. `"PS at 127.0.0.1:7700"`).
    fn describe(&self) -> String;
}

/// A fixed-size pool of pipelined connections shared round-robin by all
/// threads of a process. Requests are correlation-id tagged, so many can
/// overlap per connection; a connection that fails is dropped from its
/// slot and transparently re-dialed with the policy's jittered backoff.
pub struct ReconnectPool<R: Redial> {
    redial: R,
    policy: RetryPolicy,
    /// `None` marks a connection that died and awaits re-dialing.
    clients: Vec<Mutex<Option<PooledConn>>>,
    next: AtomicUsize,
}

impl<R: Redial> ReconnectPool<R> {
    /// Fill a pool of `conns` connections via `redial` (each one runs the
    /// full handshake; a server that rejects any of them fails the connect).
    pub fn connect(redial: R, conns: usize, policy: RetryPolicy) -> Result<ReconnectPool<R>> {
        ensure!(conns >= 1, "connection pool needs at least one connection");
        let mut clients = Vec::with_capacity(conns);
        for i in 0..conns {
            let conn = redial
                .redial()
                .with_context(|| format!("{} pool conn {i}", redial.describe()))?;
            clients.push(Mutex::new(Some(conn)));
        }
        Ok(ReconnectPool { redial, policy, clients, next: AtomicUsize::new(0) })
    }

    /// The endpoint's dial/handshake policy (protocol clients keep their
    /// recovery state — expected INFO, replay log — inside it).
    pub fn redialer(&self) -> &R {
        &self.redial
    }

    /// Clone the slot's client out (re-dialing first if the slot is empty),
    /// releasing the slot lock before any I/O happens on it.
    fn client_at(&self, slot: usize) -> Result<PooledConn> {
        let mut guard = lock_unpoisoned(&self.clients[slot]);
        if let Some(c) = guard.as_ref() {
            return Ok(c.clone());
        }
        let fresh = self.redial.redial()?;
        *guard = Some(fresh.clone());
        Ok(fresh)
    }

    /// Drop `failed` from its slot so the next caller re-dials — but only
    /// if the slot still holds that exact connection (via
    /// [`PipelinedClient::same_as`]); a replacement dialed by a faster
    /// thread stays.
    fn discard(&self, slot: usize, failed: &PooledConn) {
        let mut guard = lock_unpoisoned(&self.clients[slot]);
        if guard.as_ref().is_some_and(|c| c.same_as(failed)) {
            *guard = None;
        }
    }

    /// One RPC over the pool, transparently re-dialing a dead connection.
    ///
    /// Note on retries: idempotence is the *protocol's* job. GET/STATS/
    /// SNAPSHOT are naturally idempotent; PUT retries are either absorbed by
    /// a server-side replay cache, replay-logged, or tolerated per the
    /// paper's §4.2.4 stance — see each client's docs.
    pub fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        let slot = self.next.fetch_add(1, Ordering::Relaxed) % self.clients.len();
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.policy.attempts {
            if attempt > 0 {
                // Backoff with the slot lock released, salted by the slot
                // index: during an outage, threads on different slots
                // spread their re-dials out instead of herding.
                let d = self.policy.delay(attempt, slot as u64);
                if !d.is_zero() {
                    std::thread::sleep(d);
                }
            }
            let client = match self.client_at(slot) {
                Ok(c) => c,
                Err(e) => {
                    last_err = Some(e);
                    continue;
                }
            };
            match client.call(msg) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    // Connection is toast (peer died, frame torn, deadline
                    // blown): drop it so the next attempt re-dials.
                    self.discard(slot, &client);
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran")).context(Unreachable {
            what: self.redial.describe(),
            attempts: self.policy.attempts,
        })
    }

    /// Start one RPC without blocking for its response: the request goes
    /// out pipelined on the slot's connection, and the returned handle
    /// claims the reply later — so a scatter over N shards overlaps all N
    /// round-trips. If the fast path fails at any point (send or reply),
    /// [`PoolAsyncCall::wait`] falls back to the fully-retrying
    /// [`Self::call`], preserving the pool's recovery semantics.
    pub fn call_async(&self, msg: &[u8]) -> PoolAsyncCall<'_, R> {
        let slot = self.next.fetch_add(1, Ordering::Relaxed) % self.clients.len();
        let fast = match self.client_at(slot) {
            Ok(client) => match client.call_async(msg) {
                Ok(pending) => Some((client, pending)),
                Err(_) => {
                    self.discard(slot, &client);
                    None
                }
            },
            Err(_) => None,
        };
        PoolAsyncCall { pool: self, msg: msg.to_vec(), slot, fast }
    }
}

/// An in-flight pooled RPC started by [`ReconnectPool::call_async`].
/// Dropping it without [`wait`](Self::wait) abandons the request.
pub struct PoolAsyncCall<'a, R: Redial> {
    pool: &'a ReconnectPool<R>,
    /// Retained so a failed fast path can be retried from scratch.
    msg: Vec<u8>,
    slot: usize,
    fast: Option<(PooledConn, PendingReply)>,
}

impl<R: Redial> PoolAsyncCall<'_, R> {
    /// Block for the response. A pipelined fast-path failure discards the
    /// broken connection and retries the request through the pool's normal
    /// reconnect-with-backoff path (the same at-least-once semantics as
    /// [`ReconnectPool::call`]).
    pub fn wait(mut self) -> Result<Vec<u8>> {
        if let Some((client, pending)) = self.fast.take() {
            match pending.wait() {
                Ok(resp) => return Ok(resp),
                Err(_) => self.pool.discard(self.slot, &client),
            }
        }
        self.pool.call(&self.msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::rpc::RpcServer;
    use crate::comm::transport::TcpTransport;
    use crate::comm::wire::{WireReader, WireWriter};
    use std::net::TcpListener;
    use std::sync::atomic::AtomicU32;
    use std::sync::Arc;
    use std::time::Duration;

    const KIND: u32 = 0x0901;

    /// A tiny echo server on an ephemeral port; every accepted connection is
    /// served on its own thread until the process's test ends.
    fn echo_server() -> (String, Arc<AtomicU32>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let conns = Arc::new(AtomicU32::new(0));
        let conns2 = conns.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                conns2.fetch_add(1, Ordering::Relaxed);
                std::thread::spawn(move || {
                    let mut rpc = RpcServer::new();
                    rpc.register(KIND, Box::new(|msg| Ok(msg.to_vec())));
                    let t = TcpTransport::new(stream);
                    let _ = rpc.serve(&t);
                });
            }
        });
        (addr, conns)
    }

    struct EchoRedial {
        addr: String,
        handshakes: AtomicU32,
    }

    impl Redial for EchoRedial {
        fn redial(&self) -> Result<PooledConn> {
            self.handshakes.fetch_add(1, Ordering::Relaxed);
            PipelinedClient::connect(&self.addr, 8, Some(Duration::from_secs(10)))
        }

        fn describe(&self) -> String {
            format!("echo at {}", self.addr)
        }
    }

    fn msg(x: u64) -> Vec<u8> {
        let mut w = WireWriter::new(KIND);
        w.put_u64(&[x]);
        w.finish()
    }

    #[test]
    fn pool_round_robins_and_echoes() {
        let (addr, conns) = echo_server();
        let pool = ReconnectPool::connect(
            EchoRedial { addr, handshakes: AtomicU32::new(0) },
            2,
            RetryPolicy::new(2, 10),
        )
        .unwrap();
        for x in 0..6u64 {
            let resp = pool.call(&msg(x)).unwrap();
            let r = WireReader::parse(&resp).unwrap();
            assert_eq!(r.u64(0).unwrap(), vec![x]);
        }
        assert_eq!(conns.load(Ordering::Relaxed), 2, "pool should open exactly 2 conns");
        assert_eq!(pool.redialer().handshakes.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn dead_connection_is_redialed_transparently() {
        let (addr, _) = echo_server();
        let pool = ReconnectPool::connect(
            EchoRedial { addr, handshakes: AtomicU32::new(0) },
            1,
            RetryPolicy::new(3, 10),
        )
        .unwrap();
        pool.call(&msg(1)).unwrap();
        // Mark the pooled connection dead (exactly what `call` does when a
        // send fails); the next call must redial and still succeed.
        *pool.clients[0].lock().unwrap() = None;
        let resp = pool.call(&msg(2)).unwrap();
        let r = WireReader::parse(&resp).unwrap();
        assert_eq!(r.u64(0).unwrap(), vec![2]);
        assert!(pool.redialer().handshakes.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn async_calls_overlap_and_complete_out_of_order() {
        let (addr, conns) = echo_server();
        let pool = ReconnectPool::connect(
            EchoRedial { addr, handshakes: AtomicU32::new(0) },
            2,
            RetryPolicy::new(2, 10),
        )
        .unwrap();
        // All twelve go out before any response is claimed, overlapping on
        // the two pooled connections; waits happen in reverse.
        let pending: Vec<_> = (0..12u64).map(|x| pool.call_async(&msg(x))).collect();
        for (x, p) in pending.into_iter().enumerate().rev() {
            let resp = p.wait().unwrap();
            let r = WireReader::parse(&resp).unwrap();
            assert_eq!(r.u64(0).unwrap(), vec![x as u64]);
        }
        assert_eq!(conns.load(Ordering::Relaxed), 2, "pipelining must not open extra conns");
    }

    /// An echo server that drops its FIRST connection without serving it,
    /// then behaves normally — simulates a connection dying underneath a
    /// pooled client.
    fn flaky_echo_server() -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for (i, stream) in listener.incoming().flatten().enumerate() {
                if i == 0 {
                    drop(stream); // the pool's first connection dies at birth
                    continue;
                }
                std::thread::spawn(move || {
                    let mut rpc = RpcServer::new();
                    rpc.register(KIND, Box::new(|msg| Ok(msg.to_vec())));
                    let _ = rpc.serve(&TcpTransport::new(stream));
                });
            }
        });
        addr
    }

    #[test]
    fn async_call_falls_back_to_redial_on_dead_connection() {
        let pool = ReconnectPool::connect(
            EchoRedial { addr: flaky_echo_server(), handshakes: AtomicU32::new(0) },
            1,
            RetryPolicy::new(3, 10),
        )
        .unwrap();
        // The pooled connection is already dead (the server dropped it):
        // whether the async send fails up front or the reply wait does, the
        // handle must recover through the pool's redial path.
        let resp = pool.call_async(&msg(9)).wait().unwrap();
        assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![9]);
        assert!(pool.redialer().handshakes.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn poisoned_slot_is_survivable() {
        let (addr, _) = echo_server();
        let pool = Arc::new(
            ReconnectPool::connect(
                EchoRedial { addr, handshakes: AtomicU32::new(0) },
                1,
                RetryPolicy::new(2, 0),
            )
            .unwrap(),
        );
        // Panic while holding the slot lock — the poison-cascade bug this
        // fixes: one crashed thread used to make every later lock().unwrap()
        // panic too, taking the whole trainer down.
        let p2 = pool.clone();
        let _ = std::thread::spawn(move || {
            let _guard = p2.clients[0].lock().unwrap();
            panic!("poisoning the pool slot on purpose");
        })
        .join();
        assert!(pool.clients[0].is_poisoned(), "slot must actually be poisoned");
        let resp = pool.call(&msg(3)).unwrap();
        assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![3]);
    }

    #[test]
    fn unreachable_endpoint_reports_description() {
        let redial = EchoRedial { addr: "127.0.0.1:1".into(), handshakes: AtomicU32::new(0) };
        let err = ReconnectPool::connect(redial, 1, RetryPolicy::new(0, 0)).unwrap_err();
        assert!(format!("{err:#}").contains("echo at"), "{err:#}");
    }

    #[test]
    fn exhausted_retries_yield_a_typed_unreachable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            drop(listener); // refuse all redials: the endpoint is now down
            let mut rpc = RpcServer::new();
            rpc.register(KIND, Box::new(|msg| Ok(msg.to_vec())));
            let _ = rpc.serve(&TcpTransport::new(stream));
        });
        let pool = ReconnectPool::connect(
            EchoRedial { addr, handshakes: AtomicU32::new(0) },
            1,
            RetryPolicy::new(1, 1),
        )
        .unwrap();
        pool.call(&msg(1)).unwrap();
        // Kill the pooled connection so the next call must redial into the
        // closed listener and exhaust its budget.
        *pool.clients[0].lock().unwrap() = None;
        let err = pool.call(&msg(2)).unwrap_err();
        assert!(Unreachable::in_chain(&err), "{err:#}");
        assert!(format!("{err:#}").contains("unreachable after"), "{err:#}");
        // Ordinary errors are not misclassified.
        assert!(!Unreachable::in_chain(&anyhow::anyhow!("connection reset by peer")));
    }

    #[test]
    fn zero_connections_rejected() {
        let redial = EchoRedial { addr: "127.0.0.1:1".into(), handshakes: AtomicU32::new(0) };
        assert!(ReconnectPool::connect(redial, 0, RetryPolicy::new(0, 0)).is_err());
    }
}
