//! The self-healing RPC connection pool every TCP client rides on.
//!
//! [`RemotePs`](crate::service::RemotePs) and
//! [`RemoteEmbeddingWorker`](crate::service::RemoteEmbeddingWorker) used to
//! carry two private copies of the same machinery: a vector of mutex-guarded
//! connections handed out round-robin, a "drop the connection and re-dial
//! with backoff" loop, and a re-handshake that insists the server is still
//! the one originally connected. [`ReconnectPool`] is that machinery,
//! extracted once; what differs per protocol — how to dial, handshake, and
//! verify a fresh connection — lives behind the [`Redial`] trait.
//!
//! A redial is also where §4.2.4 recovery hooks in: the PS client's
//! [`Redial`] impl notices (via the INFO boot nonce) that the server is a
//! *new process* restored from a checkpoint epoch and replays its
//! [`PutReplayLog`](super::PutReplayLog) over the fresh connection before
//! the pool serves any other traffic on it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{ensure, Context, Result};

use crate::comm::rpc::RpcClient;
use crate::comm::transport::TcpTransport;

use super::retry::RetryPolicy;

/// One pooled RPC connection.
pub type PooledConn = RpcClient<TcpTransport>;

/// Dial + handshake policy of one pooled endpoint.
///
/// `redial` is called both to fill the pool initially and to replace every
/// connection that died, so it must be safe to run concurrently from
/// multiple pool slots (protocol-level recovery state, like a replay log,
/// guards itself).
pub trait Redial: Send + Sync {
    /// Dial a fresh connection, run the protocol handshake, and verify the
    /// server is (still) the endpoint originally connected — a process
    /// restarted with different flags must not silently rejoin.
    fn redial(&self) -> Result<PooledConn>;

    /// Human-readable endpoint description for error contexts
    /// (e.g. `"PS at 127.0.0.1:7700"`).
    fn describe(&self) -> String;
}

/// A fixed-size pool of mutex-guarded connections shared round-robin by all
/// threads of a process; each connection carries one request at a time, so
/// responses always match their requests without correlation-id reordering.
pub struct ReconnectPool<R: Redial> {
    redial: R,
    policy: RetryPolicy,
    /// `None` marks a connection that died and awaits re-dialing.
    clients: Vec<Mutex<Option<PooledConn>>>,
    next: AtomicUsize,
}

impl<R: Redial> ReconnectPool<R> {
    /// Fill a pool of `conns` connections via `redial` (each one runs the
    /// full handshake; a server that rejects any of them fails the connect).
    pub fn connect(redial: R, conns: usize, policy: RetryPolicy) -> Result<ReconnectPool<R>> {
        ensure!(conns >= 1, "connection pool needs at least one connection");
        let mut clients = Vec::with_capacity(conns);
        for i in 0..conns {
            let conn = redial
                .redial()
                .with_context(|| format!("{} pool conn {i}", redial.describe()))?;
            clients.push(Mutex::new(Some(conn)));
        }
        Ok(ReconnectPool { redial, policy, clients, next: AtomicUsize::new(0) })
    }

    /// The endpoint's dial/handshake policy (protocol clients keep their
    /// recovery state — expected INFO, replay log — inside it).
    pub fn redialer(&self) -> &R {
        &self.redial
    }

    /// One RPC over the pool, transparently re-dialing a dead connection.
    ///
    /// Note on retries: idempotence is the *protocol's* job. GET/STATS/
    /// SNAPSHOT are naturally idempotent; PUT retries are either absorbed by
    /// a server-side replay cache, replay-logged, or tolerated per the
    /// paper's §4.2.4 stance — see each client's docs.
    pub fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.clients.len();
        let slot = &self.clients[i];
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.policy.attempts {
            if attempt > 0 {
                // Backoff with the slot lock RELEASED: during an outage every
                // thread waiting on this slot sleeps in parallel instead of
                // queueing behind one holder's full retry schedule. (Redial
                // itself stays under the lock — connecting to a live server
                // is fast, and a dead one refuses immediately on loopback.)
                std::thread::sleep(self.policy.backoff);
            }
            let mut guard = slot.lock().unwrap();
            if guard.is_none() {
                match self.redial.redial() {
                    Ok(client) => *guard = Some(client),
                    Err(e) => {
                        last_err = Some(e);
                        continue;
                    }
                }
            }
            match guard.as_ref().expect("connection present").call(msg) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    // Connection is toast (peer died, frame torn): drop it so
                    // the next attempt re-dials instead of reusing it.
                    *guard = None;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran")).with_context(|| {
            format!(
                "{} unreachable after {} reconnect attempt(s)",
                self.redial.describe(),
                self.policy.attempts
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::rpc::RpcServer;
    use crate::comm::wire::{WireReader, WireWriter};
    use std::net::TcpListener;
    use std::sync::atomic::AtomicU32;
    use std::sync::Arc;

    const KIND: u32 = 0x0901;

    /// A tiny echo server on an ephemeral port; every accepted connection is
    /// served on its own thread until the process's test ends.
    fn echo_server() -> (String, Arc<AtomicU32>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let conns = Arc::new(AtomicU32::new(0));
        let conns2 = conns.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                conns2.fetch_add(1, Ordering::Relaxed);
                std::thread::spawn(move || {
                    let mut rpc = RpcServer::new();
                    rpc.register(KIND, Box::new(|msg| Ok(msg.to_vec())));
                    let t = TcpTransport::new(stream);
                    let _ = rpc.serve(&t);
                });
            }
        });
        (addr, conns)
    }

    struct EchoRedial {
        addr: String,
        handshakes: AtomicU32,
    }

    impl Redial for EchoRedial {
        fn redial(&self) -> Result<PooledConn> {
            self.handshakes.fetch_add(1, Ordering::Relaxed);
            Ok(RpcClient::new(TcpTransport::connect(&self.addr)?))
        }

        fn describe(&self) -> String {
            format!("echo at {}", self.addr)
        }
    }

    fn msg(x: u64) -> Vec<u8> {
        let mut w = WireWriter::new(KIND);
        w.put_u64(&[x]);
        w.finish()
    }

    #[test]
    fn pool_round_robins_and_echoes() {
        let (addr, conns) = echo_server();
        let pool = ReconnectPool::connect(
            EchoRedial { addr, handshakes: AtomicU32::new(0) },
            2,
            RetryPolicy::new(2, 10),
        )
        .unwrap();
        for x in 0..6u64 {
            let resp = pool.call(&msg(x)).unwrap();
            let r = WireReader::parse(&resp).unwrap();
            assert_eq!(r.u64(0).unwrap(), vec![x]);
        }
        assert_eq!(conns.load(Ordering::Relaxed), 2, "pool should open exactly 2 conns");
        assert_eq!(pool.redialer().handshakes.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn dead_connection_is_redialed_transparently() {
        let (addr, _) = echo_server();
        let pool = ReconnectPool::connect(
            EchoRedial { addr, handshakes: AtomicU32::new(0) },
            1,
            RetryPolicy::new(3, 10),
        )
        .unwrap();
        pool.call(&msg(1)).unwrap();
        // Mark the pooled connection dead (exactly what `call` does when a
        // send fails); the next call must redial and still succeed.
        *pool.clients[0].lock().unwrap() = None;
        let resp = pool.call(&msg(2)).unwrap();
        let r = WireReader::parse(&resp).unwrap();
        assert_eq!(r.u64(0).unwrap(), vec![2]);
        assert!(pool.redialer().handshakes.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn unreachable_endpoint_reports_description() {
        let redial = EchoRedial { addr: "127.0.0.1:1".into(), handshakes: AtomicU32::new(0) };
        let err = ReconnectPool::connect(redial, 1, RetryPolicy::new(0, 0)).unwrap_err();
        assert!(format!("{err:#}").contains("echo at"), "{err:#}");
    }

    #[test]
    fn zero_connections_rejected() {
        let redial = EchoRedial { addr: "127.0.0.1:1".into(), handshakes: AtomicU32::new(0) };
        assert!(ReconnectPool::connect(redial, 0, RetryPolicy::new(0, 0)).is_err());
    }
}
