//! Coordinated checkpoint epochs: one committed, resumable snapshot of the
//! *whole* three-tier run (paper §4.2.4, made global).
//!
//! Per-shard SNAPSHOT/RESTORE (PR 2) could save embedding state, but each
//! shard saved on its own schedule — a restore could mix embedding states
//! from different steps, and nothing at all saved the dense model, the
//! optimizer, or the data-stream positions. An **epoch** fixes all of that
//! with a two-phase protocol driven by the trainer (rank 0) at a step
//! boundary:
//!
//! ```text
//!   trainer rank 0                 every PS shard process
//!   ──────────────                 ──────────────────────
//!   PREPARE_CKPT(step) ──────────▶ write step-N/ps_node_X.ckpt.prep
//!                      ◀────────── ack (all shards, or abort)
//!   COMMIT_CKPT(step)  ──────────▶ rename *.prep → *.ckpt,
//!                                  write shard manifest (atomic)
//!                      ◀────────── ack (all shards)
//!   write step-N/global.manifest   (dense params + optimizer + cursors)
//!   write LATEST = N               (atomic pointer)
//! ```
//!
//! Every file lands via [`atomic_write`] (temp + fsync + rename), and each
//! guard is ordered so a crash at ANY point leaves only ignorable garbage:
//! a `.prep` file without a commit is never read; a shard manifest exists
//! only after its node files are in place; `global.manifest` exists only
//! after every shard committed; `LATEST` only after the manifest. Resume
//! ([`latest_epoch`] + [`load_manifest`]) therefore can never observe a
//! mixed-epoch state — it either finds a fully committed epoch or none.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::comm::wire::{WireReader, WireWriter};
use crate::embedding::checkpoint::crc32;
use crate::worker::EmbComm;

/// Leading magic of a serialized [`GlobalManifest`].
const MANIFEST_MAGIC: &[u8; 8] = b"PRSAGM01";
/// Wire-message kind of the manifest body (file-local, not a network kind).
const KIND_MANIFEST: u32 = 0x7F01;

/// When and where a trainer cuts checkpoint epochs.
#[derive(Clone, Debug)]
pub struct EpochConfig {
    /// Root checkpoint directory shared by the run's global manifest and
    /// (when co-located, as in the tests) the PS shards' epoch files.
    pub dir: PathBuf,
    /// Cut an epoch every this many steps (at step boundaries).
    pub every: usize,
}

impl EpochConfig {
    /// Error on a configuration that can never cut an epoch.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.every >= 1, "checkpoint cadence must be >= 1 step");
        ensure!(!self.dir.as_os_str().is_empty(), "checkpoint dir must be non-empty");
        Ok(())
    }
}

/// Everything beyond the embedding PS that a resumable run must restore:
/// the dense replica, its optimizer, and where every rank's loader stream
/// stood at the boundary. (Loader RNGs are deterministic functions of the
/// seed, so a cursor — batches drawn — IS the stream state.)
#[derive(Clone, Debug, PartialEq)]
pub struct GlobalManifest {
    /// The epoch's step boundary: training resumes at exactly this step.
    pub step: u64,
    /// [`Trainer::config_fingerprint`](crate::hybrid::Trainer::config_fingerprint)
    /// of the run — a resume with different numeric flags is rejected.
    pub fingerprint: u64,
    /// NN-worker world size the cursors are indexed by.
    pub world: usize,
    /// Batches drawn per rank at the boundary (all equal `step` in the
    /// lock-step trainer; recorded per rank for forward compatibility).
    pub loader_cursors: Vec<u64>,
    /// Dense optimizer kind code (0 = SGD, 1 = momentum, 2 = Adam).
    pub opt_kind: u64,
    /// Dense optimizer step counter (Adam bias correction).
    pub opt_t: u64,
    /// Dense parameters, flat artifact order (identical on every rank at a
    /// FullSync/deterministic boundary).
    pub params: Vec<f32>,
    /// Optimizer first-moment state (empty for SGD).
    pub opt_m: Vec<f32>,
    /// Optimizer second-moment state (empty for SGD/momentum).
    pub opt_v: Vec<f32>,
    /// Routing epoch of the PS fleet at the boundary (0 before any live
    /// reshard). A resume started against a fleet that resharded since the
    /// checkpoint can detect the skew and refresh its routing table.
    pub routing_epoch: u64,
}

impl GlobalManifest {
    /// Serialize: magic, CRC-32 of the body, then the wire-format body.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = WireWriter::new(KIND_MANIFEST);
        w.put_u64(&[
            self.step,
            self.fingerprint,
            self.world as u64,
            self.opt_kind,
            self.opt_t,
            self.routing_epoch,
        ]);
        w.put_u64(&self.loader_cursors);
        w.put_f32(&self.params);
        w.put_f32(&self.opt_m);
        w.put_f32(&self.opt_v);
        let body = w.finish();
        let mut out = Vec::with_capacity(12 + body.len());
        out.extend_from_slice(MANIFEST_MAGIC);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Parse + validate. Arbitrary, truncated, or bit-flipped bytes return
    /// `Err` — never a panic, and never a structurally inconsistent
    /// manifest (the resume property test pins this).
    pub fn from_bytes(bytes: &[u8]) -> Result<GlobalManifest> {
        ensure!(bytes.len() >= 12, "manifest too short ({} bytes)", bytes.len());
        ensure!(&bytes[..8] == MANIFEST_MAGIC, "manifest magic mismatch");
        let want = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        let body = &bytes[12..];
        ensure!(crc32(body) == want, "manifest CRC mismatch (torn write?)");
        let r = WireReader::parse(body)?;
        ensure!(r.kind() == KIND_MANIFEST, "manifest body kind {:#x}", r.kind());
        let head = r.u64(0)?;
        // 5 fields = pre-resharding manifests (implicit routing epoch 0);
        // 6 fields = current format with the routing epoch appended.
        ensure!(
            (5..=6).contains(&head.len()),
            "manifest header has {} fields",
            head.len()
        );
        let m = GlobalManifest {
            step: head[0],
            fingerprint: head[1],
            world: head[2] as usize,
            opt_kind: head[3],
            opt_t: head[4],
            loader_cursors: r.u64(1)?,
            params: r.f32(2)?,
            opt_m: r.f32(3)?,
            opt_v: r.f32(4)?,
            routing_epoch: head.get(5).copied().unwrap_or(0),
        };
        ensure!(m.opt_kind <= 2, "unknown dense optimizer code {}", m.opt_kind);
        ensure!(!m.params.is_empty(), "manifest carries no dense parameters");
        ensure!(
            m.world >= 1 && m.loader_cursors.len() == m.world,
            "manifest has {} loader cursors for world {}",
            m.loader_cursors.len(),
            m.world
        );
        // A cursor disagreeing with the epoch step would splice two
        // different moments of the run together — exactly the mixed-epoch
        // state epochs exist to rule out.
        ensure!(
            m.loader_cursors.iter().all(|&c| c == m.step),
            "manifest loader cursors {:?} disagree with epoch step {}",
            m.loader_cursors,
            m.step
        );
        ensure!(
            m.opt_m.is_empty() || m.opt_m.len() == m.params.len(),
            "optimizer m state length {} != params {}",
            m.opt_m.len(),
            m.params.len()
        );
        ensure!(
            m.opt_v.is_empty() || m.opt_v.len() == m.params.len(),
            "optimizer v state length {} != params {}",
            m.opt_v.len(),
            m.params.len()
        );
        Ok(m)
    }
}

/// The directory of checkpoint epoch `step` under `root` (`root/step-N`).
/// The single definition of the on-disk epoch layout — the coordinator's
/// global manifests and the shards' node files
/// ([`CheckpointManager`](crate::embedding::CheckpointManager)) both live
/// under it.
pub fn epoch_dir(root: &Path, step: u64) -> PathBuf {
    root.join(format!("step-{step}"))
}

/// Inverse of [`epoch_dir`]'s naming: parse a `step-N` directory name back
/// to its step (used by every committed-epoch discovery scan).
pub fn parse_epoch_dir_name(name: &str) -> Option<u64> {
    name.strip_prefix("step-").and_then(|s| s.parse().ok())
}

/// Crash-safe file write: temp file in the same directory, contents
/// fsynced, then renamed over `path` (and the directory synced,
/// best-effort). A reader can observe the old file or the new file, never
/// a torn mix — the invariant every checkpoint file in the system now
/// rides on.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("atomic_write target {} has no file name", path.display()))?;
    let tmp = path.with_file_name(format!("{file_name}.tmp-{}", std::process::id()));
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))?;
    if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        // Durable rename needs the directory synced too; not all platforms
        // allow opening directories, so this half is best-effort.
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Drive one full checkpoint epoch at step boundary `step`: the two-phase
/// PREPARE/COMMIT across every PS shard (through the embedding tier — local
/// struct, remote shards, or remote embedding workers alike), then the
/// global manifest, then the `LATEST` pointer. Ordering is the crash-safety
/// argument: each artifact exists only once everything it depends on is
/// durable.
pub fn run_epoch(
    root: &Path,
    step: u64,
    tier: &dyn EmbComm,
    manifest: &GlobalManifest,
) -> Result<()> {
    ensure!(manifest.step == step, "manifest step {} != epoch step {step}", manifest.step);
    tier.checkpoint_epoch(root, step)
        .with_context(|| format!("PS checkpoint epoch at step {step}"))?;
    let edir = epoch_dir(root, step);
    std::fs::create_dir_all(&edir)
        .with_context(|| format!("creating epoch dir {}", edir.display()))?;
    atomic_write(&edir.join("global.manifest"), &manifest.to_bytes())?;
    atomic_write(&root.join("LATEST"), step.to_string().as_bytes())?;
    Ok(())
}

/// The newest fully committed epoch under `root`, if any: an epoch counts
/// only when its `global.manifest` parses — which by write ordering implies
/// every shard committed first. Follows the `LATEST` pointer when valid and
/// falls back to scanning `step-*` directories, so a corrupt or missing
/// pointer degrades to the newest *provably complete* epoch instead of an
/// error.
pub fn latest_epoch(root: &Path) -> Option<u64> {
    if let Ok(s) = std::fs::read_to_string(root.join("LATEST")) {
        if let Ok(step) = s.trim().parse::<u64>() {
            if load_manifest(root, step).is_ok() {
                return Some(step);
            }
        }
    }
    let mut best: Option<u64> = None;
    let entries = std::fs::read_dir(root).ok()?;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(step) = name.to_str().and_then(parse_epoch_dir_name) else {
            continue;
        };
        let newer = match best {
            Some(b) => step > b,
            None => true,
        };
        if newer && load_manifest(root, step).is_ok() {
            best = Some(step);
        }
    }
    best
}

/// Load + validate the global manifest of epoch `step` under `root`.
pub fn load_manifest(root: &Path, step: u64) -> Result<GlobalManifest> {
    let path = epoch_dir(root, step).join("global.manifest");
    let bytes =
        std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    let m = GlobalManifest::from_bytes(&bytes)
        .with_context(|| format!("parsing {}", path.display()))?;
    ensure!(m.step == step, "manifest in step-{step}/ records step {}", m.step);
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(step: u64) -> GlobalManifest {
        GlobalManifest {
            step,
            fingerprint: 0xfeed_beef,
            world: 2,
            loader_cursors: vec![step, step],
            opt_kind: 0,
            opt_t: step,
            params: vec![1.0, -2.5, 3.25],
            opt_m: Vec::new(),
            opt_v: Vec::new(),
            routing_epoch: 2,
        }
    }

    fn tmp_root(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("persia_coord_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn manifest_roundtrips() {
        let m = sample(40);
        let back = GlobalManifest::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn manifest_rejects_corruption_without_panicking() {
        let bytes = sample(8).to_bytes();
        assert!(GlobalManifest::from_bytes(&[]).is_err());
        assert!(GlobalManifest::from_bytes(&bytes[..bytes.len() / 2]).is_err());
        for i in [0usize, 9, 13, bytes.len() - 1] {
            let mut b = bytes.clone();
            b[i] ^= 0xff;
            assert!(GlobalManifest::from_bytes(&b).is_err(), "flip at {i} accepted");
        }
    }

    #[test]
    fn manifest_accepts_legacy_five_field_header() {
        // Pre-resharding manifests carried 5 header words; they must still
        // parse, with the routing epoch defaulting to 0.
        let m = sample(6);
        let mut w = WireWriter::new(KIND_MANIFEST);
        w.put_u64(&[m.step, m.fingerprint, m.world as u64, m.opt_kind, m.opt_t]);
        w.put_u64(&m.loader_cursors);
        w.put_f32(&m.params);
        w.put_f32(&m.opt_m);
        w.put_f32(&m.opt_v);
        let body = w.finish();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MANIFEST_MAGIC);
        bytes.extend_from_slice(&crc32(&body).to_le_bytes());
        bytes.extend_from_slice(&body);
        let back = GlobalManifest::from_bytes(&bytes).unwrap();
        assert_eq!(back.routing_epoch, 0);
        assert_eq!(back.step, m.step);
        assert_eq!(back.params, m.params);
    }

    #[test]
    fn manifest_rejects_mixed_cursors() {
        let mut m = sample(10);
        m.loader_cursors = vec![10, 9];
        assert!(GlobalManifest::from_bytes(&m.to_bytes()).is_err());
    }

    #[test]
    fn atomic_write_then_read_back() {
        let root = tmp_root("aw");
        let p = root.join("file.bin");
        atomic_write(&p, b"hello").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"hello");
        atomic_write(&p, b"world").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"world");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn latest_epoch_ignores_uncommitted_and_corrupt_epochs() {
        let root = tmp_root("latest");
        // Epoch 10: fully committed.
        std::fs::create_dir_all(epoch_dir(&root, 10)).unwrap();
        atomic_write(&epoch_dir(&root, 10).join("global.manifest"), &sample(10).to_bytes())
            .unwrap();
        atomic_write(&root.join("LATEST"), b"10").unwrap();
        assert_eq!(latest_epoch(&root), Some(10));
        // Epoch 20: directory exists, manifest missing (crash mid-commit).
        std::fs::create_dir_all(epoch_dir(&root, 20)).unwrap();
        atomic_write(&root.join("LATEST"), b"20").unwrap();
        assert_eq!(latest_epoch(&root), Some(10), "uncommitted epoch must be ignored");
        // Epoch 30: manifest bit-flipped.
        std::fs::create_dir_all(epoch_dir(&root, 30)).unwrap();
        let mut bytes = sample(30).to_bytes();
        bytes[20] ^= 0x40;
        atomic_write(&epoch_dir(&root, 30).join("global.manifest"), &bytes).unwrap();
        atomic_write(&root.join("LATEST"), b"30").unwrap();
        assert_eq!(latest_epoch(&root), Some(10), "corrupt epoch must be ignored");
        // No epochs at all.
        let empty = tmp_root("latest_empty");
        assert_eq!(latest_epoch(&empty), None);
        std::fs::remove_dir_all(&root).ok();
        std::fs::remove_dir_all(&empty).ok();
    }

    #[test]
    fn load_manifest_rejects_step_mismatch() {
        let root = tmp_root("mismatch");
        std::fs::create_dir_all(epoch_dir(&root, 5)).unwrap();
        // A step-7 manifest parked in step-5/ must not pass for epoch 5.
        atomic_write(&epoch_dir(&root, 5).join("global.manifest"), &sample(7).to_bytes())
            .unwrap();
        assert!(load_manifest(&root, 5).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn epoch_config_validation() {
        EpochConfig { dir: "/tmp/x".into(), every: 1 }.validate().unwrap();
        assert!(EpochConfig { dir: "/tmp/x".into(), every: 0 }.validate().is_err());
        assert!(EpochConfig { dir: PathBuf::new(), every: 2 }.validate().is_err());
    }
}
