//! Replay state that turns "a process died" into "nothing happened".
//!
//! Two complementary caches live here:
//!
//! * [`PutReplayLog`] — **client-side**, per PS shard. Records every
//!   successfully applied gradient-put batch since the last committed
//!   checkpoint epoch. When the shard process is killed and comes back
//!   restored from that epoch (a *new* boot nonce in its INFO handshake),
//!   the log is replayed over the fresh connection in original apply order,
//!   reconstructing the exact pre-crash state — in deterministic mode,
//!   bitwise. Committing an epoch truncates the log, which bounds its
//!   memory by the checkpoint cadence.
//! * [`ReplayRing`] — **server-side**, a bounded response cache keyed by
//!   request identity. A client that reconnects after losing a response
//!   retries the identical request; answering from the ring keeps
//!   non-idempotent RPCs (NEXT_BATCH's stream draw, PUSH_GRADS's buffer
//!   take) idempotent across retries. Generalizes the embedding worker's
//!   PR-4 one-deep cache to a configurable depth (`--replay-depth`), so a
//!   burst of lost responses no longer desyncs a rank.

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;
use std::sync::Mutex;

use anyhow::Result;

/// One recorded gradient-put batch, tagged with who applied it and against
/// which server boot. The tags exist for *multi-owner* replay: when an
/// embedding worker dies and a survivor adopts its ranks, the dead worker's
/// retained delta can be handed to the adopter
/// ([`PutReplayLog::export_entries`] / [`PutReplayLog::adopt_entries`])
/// without forgetting whose completion order each entry belongs to.
#[derive(Clone, Debug, PartialEq)]
pub struct LogEntry {
    /// Identity of the process that applied this put (`--ew-rank` for an
    /// embedding worker, the NN rank for a direct-`--remote-ps` trainer).
    pub owner: u64,
    /// Boot nonce of the PS instance the put was applied to.
    pub boot: u64,
    /// Packed row keys of the batch.
    pub keys: Vec<u64>,
    /// Gradient rows, `keys.len() * dim` floats.
    pub grads: Vec<f32>,
}

/// Per-shard log of applied gradient-put batches since the last committed
/// checkpoint epoch (client side of the §4.2.4 exact-recovery path).
///
/// Exact replay needs the entries in *apply order*. Within one owner that
/// order is this client's completion order, which the log records directly.
/// Across owners (a dead embedding worker's delta adopted by a survivor)
/// no total order existed in the first place — the owners were separate
/// processes racing on the wire — so an adopted delta is appended after the
/// adopter's own entries and each entry keeps its `(owner, boot)` tag: the
/// replayed state is one of the interleavings that could have happened
/// live, which is exactly as strong a guarantee as the original run gave.
/// What is **not** supported is dropping an owner's delta on the floor: a
/// replay that silently omits a dead owner's puts reconstructs a state no
/// run ever produced, which is why the embedding tier refuses failover away
/// from a worker that advertised an active replay log (its log died with
/// the process and cannot be handed over).
pub struct PutReplayLog {
    /// Maximum retained entries; 0 disables the log entirely (record and
    /// replay become no-ops).
    cap: usize,
    /// Owner tag stamped on entries this process records.
    owner: u64,
    inner: Mutex<LogInner>,
}

struct LogInner {
    /// Applied put batches since the oldest retained commit, in apply order.
    entries: VecDeque<LogEntry>,
    /// Absolute index of `entries[0]` in the all-time record sequence.
    base: u64,
    /// Committed checkpoint epochs as `(epoch step, absolute log index at
    /// commit)`, ascending. Starts with the implicit epoch 0 at position 0
    /// (a fresh server's state).
    commits: Vec<(u64, u64)>,
    /// Boot nonce of the server instance whose state already includes
    /// everything recorded so far (replaying into it would double-apply).
    synced_boot: u64,
    /// Mid-replay progress `(boot nonce, next absolute index to send)`: a
    /// replay that failed partway (transient wire error while the server
    /// stayed up) resumes AFTER its last acknowledged batch instead of
    /// re-sending — and double-applying — the prefix.
    progress: Option<(u64, u64)>,
}

impl PutReplayLog {
    /// A log retaining at most `cap` put batches, owned by process 0.
    pub fn new(cap: usize) -> Self {
        Self::with_owner(cap, 0)
    }

    /// A log retaining at most `cap` put batches, stamping `owner` on every
    /// entry it records (`RecoveryConfig::replay_owner`).
    pub fn with_owner(cap: usize, owner: u64) -> Self {
        Self {
            cap,
            owner,
            inner: Mutex::new(LogInner {
                entries: VecDeque::new(),
                base: 0,
                commits: vec![(0, 0)],
                synced_boot: 0,
                progress: None,
            }),
        }
    }

    /// A disabled log: `record`/`replay_after_reconnect` are no-ops. Used
    /// when `RecoveryConfig::replay_puts` is off, so the default path pays
    /// nothing.
    pub fn disabled() -> Self {
        Self::new(0)
    }

    /// Whether this log records anything at all.
    pub fn is_enabled(&self) -> bool {
        self.cap > 0
    }

    /// Declare `boot` the server instance whose state matches everything
    /// recorded so far (called once after the initial INFO handshake).
    pub fn sync_boot(&self, boot: u64) {
        self.inner.lock().unwrap().synced_boot = boot;
    }

    /// Record one successfully applied put batch. Oldest entries beyond the
    /// cap are dropped (a later replay across them becomes best-effort).
    pub fn record(&self, keys: &[u64], grads: &[f32]) {
        if self.cap == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let boot = inner.synced_boot;
        inner.entries.push_back(LogEntry {
            owner: self.owner,
            boot,
            keys: keys.to_vec(),
            grads: grads.to_vec(),
        });
        while inner.entries.len() > self.cap {
            inner.entries.pop_front();
            inner.base += 1;
        }
    }

    /// Snapshot every retained entry, tags included, for hand-off to an
    /// adopting process's log. The entries stay in this log too — export is
    /// a copy, not a drain — because the exporting side may still need them
    /// for its own reconnect replay.
    pub fn export_entries(&self) -> Vec<LogEntry> {
        self.inner.lock().unwrap().entries.iter().cloned().collect()
    }

    /// Append another owner's exported delta to this log, preserving each
    /// entry's original `(owner, boot)` tag. Appending counts against the
    /// cap exactly like locally recorded entries; a later replay re-sends
    /// adopted entries interleaved after this owner's own retained window
    /// (see the type-level doc for why that ordering is sound).
    pub fn adopt_entries(&self, entries: Vec<LogEntry>) {
        if self.cap == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        for e in entries {
            inner.entries.push_back(e);
            while inner.entries.len() > self.cap {
                inner.entries.pop_front();
                inner.base += 1;
            }
        }
    }

    /// Number of currently retained entries (tests + diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Whether the log currently retains nothing.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every retained entry without touching the commit history
    /// (returns how many were discarded). Called when a routing reshard
    /// commits: the retained window was recorded against the pre-migration
    /// routing, so replaying it into a restarted shard would push migrated
    /// keys into a process that no longer owns them. A replay attempted
    /// before the next committed epoch reports the cleared window as
    /// dropped-beyond-cap (best-effort), which is exactly its new status.
    pub fn clear(&self) -> usize {
        if self.cap == 0 {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        let n = inner.entries.len();
        inner.base += n as u64;
        inner.entries.clear();
        inner.progress = None;
        n
    }

    /// Mark checkpoint epoch `step` committed at the current log position:
    /// entries recorded before the *previous* commit can never be needed
    /// again (a server restores its newest committed epoch; one epoch of
    /// slack is kept for a server forced onto the previous one) and are
    /// pruned.
    pub fn mark_committed(&self, step: u64) {
        if self.cap == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let pos = inner.base + inner.entries.len() as u64;
        inner.commits.push((step, pos));
        // Keep the last two commit positions reachable; drop entries before
        // the second-newest commit.
        if inner.commits.len() >= 2 {
            let keep_from = inner.commits[inner.commits.len() - 2].1;
            while inner.base < keep_from && !inner.entries.is_empty() {
                inner.entries.pop_front();
                inner.base += 1;
            }
        }
        // The commit list itself stays tiny.
        while inner.commits.len() > 8 {
            inner.commits.remove(0);
        }
    }

    /// Bring a reconnected server instance (`boot`, freshly restored from
    /// checkpoint epoch `restored_step`) back to this client's state by
    /// re-sending every logged put recorded after that epoch, in order,
    /// through `send`. Idempotent per boot: the first pool slot to redial
    /// performs the replay, later slots see the nonce already synced and do
    /// nothing. On a `send` error the boot stays unsynced — the redial
    /// fails and the next one resumes the replay — but progress is tracked
    /// per acknowledged batch, so the already-applied prefix is never
    /// re-sent into a still-alive server (re-applying gradients would
    /// silently corrupt the optimizer state the replay exists to restore).
    ///
    /// Returns the number of batches replayed by this call.
    pub fn replay_after_reconnect(
        &self,
        boot: u64,
        restored_step: u64,
        what: &str,
        send: &mut dyn FnMut(&[u64], &[f32]) -> Result<()>,
    ) -> Result<usize> {
        if self.cap == 0 {
            return Ok(0);
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.synced_boot == boot {
            return Ok(0);
        }
        let found = inner
            .commits
            .iter()
            .rev()
            .find(|(s, _)| *s == restored_step)
            .map(|&(_, pos)| pos);
        let newest = inner.commits.last().copied().unwrap_or((0, 0));
        let start = match found {
            Some(pos) => pos,
            None if restored_step > newest.0 => {
                // The server restored an epoch this client never saw commit
                // (a crash between the shard's rename and the global mark):
                // its state is AHEAD of every position we know, so replaying
                // anything could double-apply. Resync and say so loudly.
                eprintln!(
                    "recovery: {what} restored epoch {restored_step}, newer than the newest \
                     epoch this client recorded ({}); skipping replay — updates between the \
                     two may be lost",
                    newest.0
                );
                inner.synced_boot = boot;
                inner.progress = None;
                return Ok(0);
            }
            None => {
                eprintln!(
                    "recovery: {what} restored epoch {restored_step}, older than this \
                     client's retained log; replaying the whole retained window"
                );
                inner.base
            }
        };
        // Resume a partial replay into the SAME boot after its last
        // acknowledged batch (a new boot starts over from the epoch).
        let start = match inner.progress {
            Some((b, next)) if b == boot => next.max(start),
            _ => start,
        };
        if start < inner.base {
            eprintln!(
                "recovery: {what} replay is missing {} put batch(es) dropped beyond the \
                 replay cap; recovered state may diverge",
                inner.base - start
            );
        }
        let mut idx = start.saturating_sub(inner.base) as usize;
        let mut n = 0usize;
        while idx < inner.entries.len() {
            {
                let e = &inner.entries[idx];
                send(&e.keys, &e.grads)?;
            }
            idx += 1;
            n += 1;
            inner.progress = Some((boot, inner.base + idx as u64));
        }
        inner.synced_boot = boot;
        inner.progress = None;
        Ok(n)
    }
}

/// Bounded response-replay cache: the last `depth` responses keyed by
/// request identity, oldest evicted first. Not internally locked — callers
/// wrap it in whatever granularity of mutex their concurrency needs (the
/// embedding worker keeps one ring per NN rank so retries of one rank
/// serialize while other ranks proceed).
pub struct ReplayRing<K: Hash + Eq + Clone, V> {
    depth: usize,
    order: VecDeque<K>,
    map: HashMap<K, V>,
}

impl<K: Hash + Eq + Clone, V> ReplayRing<K, V> {
    /// A ring caching the last `depth` responses (`depth >= 1`).
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1, "replay ring depth must be >= 1");
        Self { depth, order: VecDeque::new(), map: HashMap::new() }
    }

    /// The configured capacity.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The cached response for `key`, if still retained.
    pub fn get(&self, key: &K) -> Option<&V> {
        self.map.get(key)
    }

    /// Cache `value` under `key`, evicting the oldest entry beyond the
    /// depth. Re-inserting an existing key replaces its value in place.
    pub fn insert(&mut self, key: K, value: V) {
        if self.map.insert(key.clone(), value).is_none() {
            self.order.push_back(key);
            if self.order.len() > self.depth {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect_replay(log: &PutReplayLog, boot: u64, restored: u64) -> Vec<Vec<u64>> {
        let mut seen = Vec::new();
        log.replay_after_reconnect(boot, restored, "test shard", &mut |keys, _grads| {
            seen.push(keys.to_vec());
            Ok(())
        })
        .unwrap();
        seen
    }

    #[test]
    fn replays_everything_after_the_restored_epoch() {
        let log = PutReplayLog::new(64);
        log.sync_boot(1);
        log.record(&[1], &[0.1]);
        log.record(&[2], &[0.2]);
        log.mark_committed(10);
        log.record(&[3], &[0.3]);
        log.record(&[4], &[0.4]);
        // Same boot: nothing to do.
        assert!(collect_replay(&log, 1, 10).is_empty());
        // New boot restored from epoch 10: entries 3 and 4 replay, in order.
        assert_eq!(collect_replay(&log, 2, 10), vec![vec![3], vec![4]]);
        // Replay is idempotent per boot.
        assert!(collect_replay(&log, 2, 10).is_empty());
    }

    #[test]
    fn fresh_server_replays_from_epoch_zero() {
        let log = PutReplayLog::new(64);
        log.sync_boot(7);
        log.record(&[1], &[0.0]);
        log.record(&[2], &[0.0]);
        assert_eq!(collect_replay(&log, 8, 0), vec![vec![1], vec![2]]);
    }

    #[test]
    fn commit_prunes_entries_before_the_previous_commit() {
        let log = PutReplayLog::new(64);
        log.record(&[1], &[0.0]);
        log.mark_committed(4);
        log.record(&[2], &[0.0]);
        log.mark_committed(8);
        // Entry 1 (before commit 4, the second-newest) is pruned; entry 2
        // (between 4 and 8) is retained for a server forced onto epoch 4.
        assert_eq!(log.len(), 1);
        assert_eq!(collect_replay(&log, 9, 4), vec![vec![2]]);
        let log2 = PutReplayLog::new(64);
        log2.record(&[1], &[0.0]);
        log2.mark_committed(4);
        log2.record(&[2], &[0.0]);
        log2.mark_committed(8);
        assert!(collect_replay(&log2, 9, 8).is_empty());
    }

    #[test]
    fn clear_drops_the_window_but_later_records_still_replay() {
        let log = PutReplayLog::new(8);
        log.sync_boot(1);
        log.record(&[1], &[0.0]);
        log.record(&[2], &[0.0]);
        assert_eq!(log.clear(), 2);
        assert!(log.is_empty());
        // The cleared window is gone for good (best-effort from epoch 0)…
        assert!(collect_replay(&log, 2, 0).is_empty());
        // …but entries recorded after the clear replay normally from the
        // next committed epoch.
        log.sync_boot(2);
        log.record(&[3], &[0.0]);
        log.mark_committed(10);
        log.record(&[4], &[0.0]);
        assert_eq!(collect_replay(&log, 3, 10), vec![vec![4]]);
        // A disabled log clears nothing.
        assert_eq!(PutReplayLog::disabled().clear(), 0);
    }

    #[test]
    fn newer_epoch_than_recorded_skips_replay() {
        let log = PutReplayLog::new(64);
        log.record(&[1], &[0.0]);
        log.mark_committed(4);
        log.record(&[2], &[0.0]);
        // Server claims epoch 12, which this client never saw commit.
        assert!(collect_replay(&log, 3, 12).is_empty());
    }

    #[test]
    fn cap_overflow_drops_oldest_and_still_replays_rest() {
        let log = PutReplayLog::new(2);
        log.record(&[1], &[0.0]);
        log.record(&[2], &[0.0]);
        log.record(&[3], &[0.0]);
        assert_eq!(log.len(), 2);
        // Epoch 0's position predates the retained window: best-effort.
        assert_eq!(collect_replay(&log, 5, 0), vec![vec![2], vec![3]]);
    }

    #[test]
    fn failed_send_keeps_boot_unsynced_for_a_retry() {
        let log = PutReplayLog::new(8);
        log.record(&[1], &[0.0]);
        let mut calls = 0;
        let res = log.replay_after_reconnect(2, 0, "t", &mut |_k, _g| {
            calls += 1;
            anyhow::bail!("wire died mid-replay")
        });
        assert!(res.is_err());
        assert_eq!(calls, 1);
        // Nothing was acknowledged, so the retry replays from the top.
        assert_eq!(collect_replay(&log, 2, 0), vec![vec![1]]);
    }

    #[test]
    fn partial_replay_resumes_after_the_acknowledged_prefix() {
        let log = PutReplayLog::new(8);
        log.record(&[1], &[0.0]);
        log.record(&[2], &[0.0]);
        log.record(&[3], &[0.0]);
        // First attempt applies batches 1 and 2, then the wire dies.
        let mut sent = Vec::new();
        let res = log.replay_after_reconnect(5, 0, "t", &mut |keys, _g| {
            if sent.len() == 2 {
                anyhow::bail!("wire died after two batches");
            }
            sent.push(keys.to_vec());
            Ok(())
        });
        assert!(res.is_err());
        assert_eq!(sent, vec![vec![1], vec![2]]);
        // Same boot is still alive: the retry must NOT re-apply 1 and 2.
        assert_eq!(collect_replay(&log, 5, 0), vec![vec![3]]);
        // A *different* boot (the server died again, restored from the
        // epoch) starts over from the epoch position.
        assert_eq!(collect_replay(&log, 6, 0), vec![vec![1], vec![2], vec![3]]);
    }

    #[test]
    fn entries_are_stamped_with_owner_and_boot() {
        let log = PutReplayLog::with_owner(8, 3);
        log.sync_boot(77);
        log.record(&[1], &[0.5]);
        let exported = log.export_entries();
        assert_eq!(exported.len(), 1);
        assert_eq!(exported[0].owner, 3);
        assert_eq!(exported[0].boot, 77);
        assert_eq!(exported[0].keys, vec![1]);
        assert_eq!(exported[0].grads, vec![0.5]);
    }

    #[test]
    fn adopted_delta_replays_after_own_entries_with_tags_preserved() {
        // A dead owner-7 log hands its delta to a surviving owner-3 log.
        let dead = PutReplayLog::with_owner(8, 7);
        dead.sync_boot(50);
        dead.record(&[10], &[0.0]);
        dead.record(&[11], &[0.0]);

        let survivor = PutReplayLog::with_owner(8, 3);
        survivor.sync_boot(50);
        survivor.record(&[1], &[0.0]);
        survivor.adopt_entries(dead.export_entries());
        assert_eq!(survivor.len(), 3);
        // Tags survive adoption untouched.
        let all = survivor.export_entries();
        assert_eq!(all.iter().map(|e| e.owner).collect::<Vec<_>>(), vec![3, 7, 7]);
        // A restarted shard gets BOTH owners' windows, own entries first.
        assert_eq!(collect_replay(&survivor, 51, 0), vec![vec![1], vec![10], vec![11]]);
    }

    #[test]
    fn adopted_entries_count_against_the_cap() {
        let survivor = PutReplayLog::with_owner(2, 0);
        survivor.record(&[1], &[0.0]);
        let dead = PutReplayLog::with_owner(2, 1);
        dead.record(&[2], &[0.0]);
        dead.record(&[3], &[0.0]);
        survivor.adopt_entries(dead.export_entries());
        assert_eq!(survivor.len(), 2);
        // Oldest (own entry 1) was evicted; replay is best-effort.
        assert_eq!(collect_replay(&survivor, 9, 0), vec![vec![2], vec![3]]);
    }

    #[test]
    fn disabled_log_ignores_adoption() {
        let log = PutReplayLog::disabled();
        log.adopt_entries(vec![LogEntry { owner: 1, boot: 2, keys: vec![3], grads: vec![0.0] }]);
        assert!(log.is_empty());
        assert!(log.export_entries().is_empty());
    }

    #[test]
    fn disabled_log_is_free() {
        let log = PutReplayLog::disabled();
        assert!(!log.is_enabled());
        log.record(&[1], &[0.0]);
        assert!(log.is_empty());
        assert!(collect_replay(&log, 2, 0).is_empty());
    }

    #[test]
    fn replay_ring_keeps_last_depth_entries() {
        let mut ring: ReplayRing<usize, Vec<u8>> = ReplayRing::new(2);
        ring.insert(0, vec![0]);
        ring.insert(1, vec![1]);
        ring.insert(2, vec![2]);
        assert!(ring.get(&0).is_none(), "oldest evicted");
        assert_eq!(ring.get(&1), Some(&vec![1]));
        assert_eq!(ring.get(&2), Some(&vec![2]));
        // Replacing a live key must not grow the ring.
        ring.insert(2, vec![9]);
        assert_eq!(ring.get(&2), Some(&vec![9]));
        assert_eq!(ring.get(&1), Some(&vec![1]));
        assert_eq!(ring.depth(), 2);
    }
}
