//! The one recovery layer every failure path goes through (paper §4.2.4 as
//! a first-class subsystem).
//!
//! Before this module, recovery was scattered: `RemotePs`,
//! `RemoteEmbeddingWorker`, the gradient appliers, and the ring rendezvous
//! each hand-rolled reconnect/retry loops, shard snapshots were
//! uncoordinated (a restore could mix embedding states from different
//! steps), and a killed process still ended the run. Everything
//! failure-shaped now lives here, configured by one
//! [`RecoveryConfig`](crate::config::RecoveryConfig):
//!
//! * [`retry`] — [`RetryPolicy`]: bounded attempts with capped-exponential,
//!   deterministically-jittered backoff (no reconnect thundering herd),
//!   plus the deadline-bounded [`dial_retry`] the ring rendezvous uses.
//! * [`pool`] — [`ReconnectPool`]: the self-healing round-robin pool of
//!   pipelined RPC connections (sync [`ReconnectPool::call`] and
//!   scatter-friendly [`ReconnectPool::call_async`]), with per-protocol
//!   dial/handshake behind [`Redial`].
//! * [`replay`] — [`PutReplayLog`] (client-side gradient-put replay into a
//!   shard restored from an older epoch) and [`ReplayRing`] (server-side
//!   bounded response replay for reconnect retries).
//! * [`coordinator`] — coordinated **checkpoint epochs**: the two-phase
//!   PREPARE/COMMIT snapshot across all PS shards, the [`GlobalManifest`]
//!   (dense model + optimizer + loader cursors), and the committed-epoch
//!   discovery that `--resume-from` builds on.
//!
//! The failure matrix this buys (see ARCHITECTURE.md for the full table):
//! SIGKILL of a single PS shard mid-run is *survived* — the pool
//! re-handshakes the restarted process, the put log replays the delta since
//! its restored epoch, re-buffered pushes drain — and a fully killed run is
//! *resumable* from its last committed epoch.

pub mod coordinator;
pub mod pool;
pub mod replay;
pub mod retry;

pub use coordinator::{
    atomic_write, epoch_dir, latest_epoch, load_manifest, parse_epoch_dir_name, run_epoch,
    EpochConfig, GlobalManifest,
};
pub use pool::{PoolAsyncCall, PooledConn, ReconnectPool, Redial, Unreachable};
pub use replay::{LogEntry, PutReplayLog, ReplayRing};
pub use retry::{dial_retry, remaining, RetryPolicy};
