//! Bounded retry with constant backoff — the one policy every failure path
//! shares.
//!
//! Before this module existed, `RemotePs`, `RemoteEmbeddingWorker`, the
//! gradient appliers, and the TCP ring rendezvous each hand-rolled their own
//! attempt loop with slightly different off-by-ones and error wording. They
//! now all build a [`RetryPolicy`] (usually from
//! [`RecoveryConfig`](crate::config::RecoveryConfig)) so "how hard do we try"
//! has exactly one meaning across the system.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::RecoveryConfig;

/// How many times to retry a failed operation, and how long to wait between
/// attempts. `attempts` counts *retries*: 0 means fail on the first error,
/// N means up to N+1 total tries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries after the first failure (total tries = `attempts + 1`).
    pub attempts: u32,
    /// Constant delay before each retry.
    pub backoff: Duration,
}

impl RetryPolicy {
    /// A policy with `attempts` retries spaced `backoff_ms` apart.
    pub fn new(attempts: u32, backoff_ms: u64) -> Self {
        Self { attempts, backoff: Duration::from_millis(backoff_ms) }
    }

    /// Run `f` until it succeeds or the retry budget is exhausted, sleeping
    /// `backoff` before every retry. The final error carries `what` and the
    /// total attempt count.
    pub fn run<T>(&self, what: &str, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.attempts {
            if attempt > 0 && !self.backoff.is_zero() {
                std::thread::sleep(self.backoff);
            }
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one attempt ran"))
            .with_context(|| format!("{what} failed after {} attempt(s)", self.attempts + 1))
    }
}

impl From<&RecoveryConfig> for RetryPolicy {
    fn from(cfg: &RecoveryConfig) -> Self {
        Self::new(cfg.attempts, cfg.backoff_ms)
    }
}

/// Time left until `deadline`, floored at 1ms so socket timeouts derived
/// from it are never zero (zero would mean "no timeout" to the OS).
pub fn remaining(deadline: Instant) -> Duration {
    deadline.saturating_duration_since(Instant::now()).max(Duration::from_millis(1))
}

/// Dial `addr`, retrying until `deadline` — the target process may not have
/// bound its listener yet (rendezvous joins, restarted shards). `what` names
/// the target in the final error.
pub fn dial_retry(addr: &str, deadline: Instant, what: &str) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("dialing {what} at {addr}"));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn succeeds_first_try_without_sleeping() {
        let p = RetryPolicy::new(3, 1_000_000); // would sleep forever if retried
        let t0 = Instant::now();
        let v = p.run("noop", || Ok::<_, anyhow::Error>(7)).unwrap();
        assert_eq!(v, 7);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn retries_until_success() {
        let p = RetryPolicy::new(4, 0);
        let mut calls = 0;
        let v = p
            .run("flaky", || {
                calls += 1;
                if calls < 3 {
                    anyhow::bail!("not yet");
                }
                Ok(calls)
            })
            .unwrap();
        assert_eq!(v, 3);
    }

    #[test]
    fn exhausted_budget_reports_what_and_count() {
        let p = RetryPolicy::new(2, 0);
        let err = p.run("doomed op", || Err::<(), _>(anyhow::anyhow!("nope"))).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("doomed op") && msg.contains("3 attempt(s)"), "{msg}");
    }

    #[test]
    fn zero_attempts_means_one_try() {
        let p = RetryPolicy::new(0, 0);
        let mut calls = 0;
        let _ = p.run("once", || {
            calls += 1;
            Err::<(), _>(anyhow::anyhow!("x"))
        });
        assert_eq!(calls, 1);
    }

    #[test]
    fn from_recovery_config() {
        let cfg = RecoveryConfig { attempts: 9, backoff_ms: 123, ..RecoveryConfig::default() };
        let p = RetryPolicy::from(&cfg);
        assert_eq!(p.attempts, 9);
        assert_eq!(p.backoff, Duration::from_millis(123));
    }

    #[test]
    fn dial_retry_times_out_on_dead_target() {
        // Port 1 on loopback is almost surely closed; the deadline bounds
        // the wait either way.
        let deadline = Instant::now() + Duration::from_millis(200);
        let err = dial_retry("127.0.0.1:1", deadline, "nothing").unwrap_err();
        assert!(format!("{err:#}").contains("nothing"));
    }
}
