//! Bounded retry with capped-exponential, deterministically-jittered
//! backoff — the one policy every failure path shares.
//!
//! Before this module existed, `RemotePs`, `RemoteEmbeddingWorker`, the
//! gradient appliers, and the TCP ring rendezvous each hand-rolled their own
//! attempt loop with slightly different off-by-ones and error wording. They
//! now all build a [`RetryPolicy`] (usually from
//! [`RecoveryConfig`](crate::config::RecoveryConfig)) so "how hard do we try"
//! has exactly one meaning across the system.
//!
//! The schedule ([`RetryPolicy::delay`]) fixes a thundering-herd bug: the
//! original policy slept a *constant* `backoff_ms`, so when a PS shard died
//! every trainer thread in the fleet re-dialed it in lock-step, again and
//! again, exactly when the restarted shard was busiest. Retry `r` now
//! sleeps `backoff · 2^(r-1)` (capped), jittered into `[d/2, d]` by a hash
//! of a caller-supplied salt (rank, pool-slot index) — deterministic per
//! client, so reproducible runs stay reproducible, but de-synchronized
//! across clients.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::RecoveryConfig;

/// How many times to retry a failed operation, and how long to wait between
/// attempts. `attempts` counts *retries*: 0 means fail on the first error,
/// N means up to N+1 total tries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries after the first failure (total tries = `attempts + 1`).
    pub attempts: u32,
    /// Base delay: retry `r` sleeps about `backoff · 2^(r-1)`, capped and
    /// jittered (see [`Self::delay`]). Zero disables sleeping entirely.
    pub backoff: Duration,
}

/// The exponential envelope stops growing here; a fleet-wide outage must
/// not turn into minute-long client stalls.
pub const BACKOFF_CAP: Duration = Duration::from_secs(10);

impl RetryPolicy {
    /// A policy with `attempts` retries and a base delay of `backoff_ms`.
    pub fn new(attempts: u32, backoff_ms: u64) -> Self {
        Self { attempts, backoff: Duration::from_millis(backoff_ms) }
    }

    /// The sleep before retry `attempt` (1-based): capped exponential with
    /// deterministic jitter. The envelope is `backoff · 2^(attempt-1)`,
    /// clamped to [`BACKOFF_CAP`]; the returned delay lands in
    /// `[envelope/2, envelope]` at a point chosen by hashing
    /// `(salt, attempt)` — so a given client retries on the exact same
    /// schedule every run (no nondeterminism), while clients with distinct
    /// salts (rank, pool-slot index) spread out instead of thundering onto
    /// a freshly-restarted server in lock-step.
    pub fn delay(&self, attempt: u32, salt: u64) -> Duration {
        if self.backoff.is_zero() {
            return Duration::ZERO;
        }
        let envelope = self
            .backoff
            .saturating_mul(1u32 << attempt.saturating_sub(1).min(5))
            .min(BACKOFF_CAP);
        // FNV-1a over (salt, attempt): cheap, deterministic, well-spread.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in salt.to_le_bytes().iter().chain(attempt.to_le_bytes().iter()) {
            h ^= u64::from(*b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        let half = envelope.as_nanos() as u64 / 2;
        Duration::from_nanos(half + h % (half + 1))
    }

    /// Run `f` until it succeeds or the retry budget is exhausted, sleeping
    /// [`Self::delay`] before every retry (salt 0; callers that want
    /// per-client jitter drive `delay` themselves, as the connection pool
    /// does). The final error carries `what` and the total attempt count.
    pub fn run<T>(&self, what: &str, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.attempts {
            if attempt > 0 {
                let d = self.delay(attempt, 0);
                if !d.is_zero() {
                    std::thread::sleep(d);
                }
            }
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one attempt ran"))
            .with_context(|| format!("{what} failed after {} attempt(s)", self.attempts + 1))
    }
}

impl From<&RecoveryConfig> for RetryPolicy {
    fn from(cfg: &RecoveryConfig) -> Self {
        Self::new(cfg.attempts, cfg.backoff_ms)
    }
}

/// Time left until `deadline`, floored at 1ms so socket timeouts derived
/// from it are never zero (zero would mean "no timeout" to the OS).
pub fn remaining(deadline: Instant) -> Duration {
    deadline.saturating_duration_since(Instant::now()).max(Duration::from_millis(1))
}

/// Dial `addr`, retrying until `deadline` — the target process may not have
/// bound its listener yet (rendezvous joins, restarted shards). `what` names
/// the target in the final error.
pub fn dial_retry(addr: &str, deadline: Instant, what: &str) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("dialing {what} at {addr}"));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn succeeds_first_try_without_sleeping() {
        let p = RetryPolicy::new(3, 1_000_000); // would sleep forever if retried
        let t0 = Instant::now();
        let v = p.run("noop", || Ok::<_, anyhow::Error>(7)).unwrap();
        assert_eq!(v, 7);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn retries_until_success() {
        let p = RetryPolicy::new(4, 0);
        let mut calls = 0;
        let v = p
            .run("flaky", || {
                calls += 1;
                if calls < 3 {
                    anyhow::bail!("not yet");
                }
                Ok(calls)
            })
            .unwrap();
        assert_eq!(v, 3);
    }

    #[test]
    fn exhausted_budget_reports_what_and_count() {
        let p = RetryPolicy::new(2, 0);
        let err = p.run("doomed op", || Err::<(), _>(anyhow::anyhow!("nope"))).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("doomed op") && msg.contains("3 attempt(s)"), "{msg}");
    }

    #[test]
    fn zero_attempts_means_one_try() {
        let p = RetryPolicy::new(0, 0);
        let mut calls = 0;
        let _ = p.run("once", || {
            calls += 1;
            Err::<(), _>(anyhow::anyhow!("x"))
        });
        assert_eq!(calls, 1);
    }

    #[test]
    fn delay_schedule_is_capped_exponential_with_jitter() {
        let p = RetryPolicy::new(8, 100);
        for attempt in 1..=8u32 {
            let envelope = Duration::from_millis(100)
                .saturating_mul(1 << attempt.saturating_sub(1).min(5))
                .min(BACKOFF_CAP);
            let d = p.delay(attempt, 42);
            assert!(
                d >= envelope / 2 && d <= envelope,
                "attempt {attempt}: {d:?} outside [{:?}, {envelope:?}]",
                envelope / 2
            );
            assert_eq!(d, p.delay(attempt, 42), "schedule must be deterministic");
        }
        // The envelope stops doubling at backoff << 5 (here 3.2s < the cap):
        // late retries share it instead of growing without bound.
        assert!(p.delay(30, 42) <= Duration::from_millis(3200));
        // A huge base delay still respects the absolute cap.
        assert!(RetryPolicy::new(8, 60_000).delay(4, 0) <= BACKOFF_CAP);
    }

    #[test]
    fn delay_jitter_separates_clients() {
        let p = RetryPolicy::new(4, 50);
        assert!(
            (1..=6u32).any(|a| p.delay(a, 0) != p.delay(a, 7)),
            "distinct salts must de-synchronize the retry herd"
        );
    }

    #[test]
    fn zero_backoff_never_sleeps() {
        let p = RetryPolicy::new(4, 0);
        for attempt in 1..=4u32 {
            assert_eq!(p.delay(attempt, 9), Duration::ZERO);
        }
    }

    #[test]
    fn from_recovery_config() {
        let cfg = RecoveryConfig { attempts: 9, backoff_ms: 123, ..RecoveryConfig::default() };
        let p = RetryPolicy::from(&cfg);
        assert_eq!(p.attempts, 9);
        assert_eq!(p.backoff, Duration::from_millis(123));
    }

    #[test]
    fn dial_retry_times_out_on_dead_target() {
        // Port 1 on loopback is almost surely closed; the deadline bounds
        // the wait either way.
        let deadline = Instant::now() + Duration::from_millis(200);
        let err = dial_retry("127.0.0.1:1", deadline, "nothing").unwrap_err();
        assert!(format!("{err:#}").contains("nothing"));
    }
}
