//! Pure-Rust dense tower (reference + fallback for the PJRT artifact).
//!
//! Implements exactly the L2 JAX model (`python/compile/model.py`): an FFNN
//! with ReLU hidden layers, a linear logit head and mean BCE-with-logits
//! loss. Used (a) as the numeric cross-check of the AOT artifact in the
//! integration tests, (b) as the dense engine when artifacts are not built,
//! and (c) to host the dense optimizer the NN workers run after AllReduce.

pub mod model;
pub mod optimizer;

pub use model::{DenseGrads, DenseModel};
pub use optimizer::{DenseOptimizer, DenseOptimizerKind};
