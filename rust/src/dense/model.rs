//! FFNN forward/backward matching python/compile/model.py semantics.

use crate::tensor::Tensor;
use crate::util::Rng;

/// Gradients of one train step: per-layer (dW, db) + grad wrt the embedding
/// input block (what flows back to the embedding workers, Alg. 2's last line).
#[derive(Clone, Debug)]
pub struct DenseGrads {
    pub weights: Vec<Tensor>,
    pub biases: Vec<Tensor>,
    /// `[B, emb_dim]` — gradient of the loss wrt the pooled embeddings.
    pub emb: Tensor,
}

/// The dense tower: weights/biases per layer, ReLU hidden, linear head.
#[derive(Clone)]
pub struct DenseModel {
    pub dims: Vec<usize>,
    pub weights: Vec<Tensor>,
    pub biases: Vec<Tensor>,
    pub emb_dim: usize,
    pub nid_dim: usize,
}

impl DenseModel {
    /// He-initialized model; `dims` = [input, hidden..., 1].
    pub fn new(dims: &[usize], emb_dim: usize, nid_dim: usize, rng: &mut Rng) -> Self {
        assert!(dims.len() >= 2 && *dims.last().unwrap() == 1);
        assert_eq!(dims[0], emb_dim + nid_dim);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for i in 0..dims.len() - 1 {
            weights.push(Tensor::he_init(&[dims[i], dims[i + 1]], rng));
            biases.push(Tensor::zeros(&[dims[i + 1]]));
        }
        Self { dims: dims.to_vec(), weights, biases, emb_dim, nid_dim }
    }

    pub fn n_layers(&self) -> usize {
        self.weights.len()
    }

    pub fn param_count(&self) -> usize {
        self.weights.iter().map(|w| w.len()).sum::<usize>()
            + self.biases.iter().map(|b| b.len()).sum::<usize>()
    }

    /// Parameters flattened in artifact order (w0, b0, w1, b1, ...).
    pub fn params_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for (w, b) in self.weights.iter().zip(&self.biases) {
            out.extend_from_slice(w.data());
            out.extend_from_slice(b.data());
        }
        out
    }

    /// Overwrite parameters from the flat artifact ordering.
    pub fn set_params_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.param_count());
        let mut off = 0;
        for (w, b) in self.weights.iter_mut().zip(self.biases.iter_mut()) {
            let n = w.len();
            w.data_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
            let m = b.len();
            b.data_mut().copy_from_slice(&flat[off..off + m]);
            off += m;
        }
    }

    fn forward_cached(&self, x0: Tensor) -> (Vec<Tensor>, Vec<Tensor>) {
        // Returns (activations x_0..x_L, pre-activations z_1..z_L).
        let mut acts = vec![x0];
        let mut zs = Vec::with_capacity(self.n_layers());
        for (l, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let mut z = acts.last().unwrap().matmul(w);
            let n = z.shape()[1];
            for row in 0..z.shape()[0] {
                for j in 0..n {
                    *z.at2_mut(row, j) += b.data()[j];
                }
            }
            let last = l == self.n_layers() - 1;
            let x = if last {
                z.clone()
            } else {
                let mut x = z.clone();
                for v in x.data_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                x
            };
            zs.push(z);
            acts.push(x);
        }
        (acts, zs)
    }

    fn concat_inputs(&self, emb: &[f32], nid: &[f32], batch: usize) -> Tensor {
        assert_eq!(emb.len(), batch * self.emb_dim);
        assert_eq!(nid.len(), batch * self.nid_dim);
        let d0 = self.dims[0];
        let mut x = vec![0.0f32; batch * d0];
        for r in 0..batch {
            x[r * d0..r * d0 + self.emb_dim]
                .copy_from_slice(&emb[r * self.emb_dim..(r + 1) * self.emb_dim]);
            x[r * d0 + self.emb_dim..(r + 1) * d0]
                .copy_from_slice(&nid[r * self.nid_dim..(r + 1) * self.nid_dim]);
        }
        Tensor::from_vec(&[batch, d0], x)
    }

    /// Predicted probabilities for a batch.
    pub fn forward(&self, emb: &[f32], nid: &[f32], batch: usize) -> Vec<f32> {
        let x0 = self.concat_inputs(emb, nid, batch);
        let (acts, _) = self.forward_cached(x0);
        acts.last()
            .unwrap()
            .data()
            .iter()
            .map(|&z| 1.0 / (1.0 + (-z).exp()))
            .collect()
    }

    /// Mean BCE-with-logits loss + full gradients (matches the artifact's
    /// `train_<preset>` outputs bit-for-bit up to float assoc.)
    pub fn train_step(
        &self,
        emb: &[f32],
        nid: &[f32],
        labels: &[f32],
        batch: usize,
    ) -> (f32, DenseGrads) {
        assert_eq!(labels.len(), batch);
        let x0 = self.concat_inputs(emb, nid, batch);
        let (acts, zs) = self.forward_cached(x0);
        let logits = acts.last().unwrap();

        // Numerically stable BCE: max(z,0) - z*y + log1p(exp(-|z|)).
        let mut loss = 0.0f64;
        for (r, &y) in labels.iter().enumerate() {
            let z = logits.at2(r, 0);
            loss += (z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()) as f64;
        }
        let loss = (loss / batch as f64) as f32;

        // dL/dz_last = (sigmoid(z) - y) / B.
        let mut dz = Tensor::zeros(&[batch, 1]);
        for (r, &y) in labels.iter().enumerate() {
            let z = logits.at2(r, 0);
            *dz.at2_mut(r, 0) = (1.0 / (1.0 + (-z).exp()) - y) / batch as f32;
        }

        let mut dws = vec![Tensor::zeros(&[1]); self.n_layers()];
        let mut dbs = vec![Tensor::zeros(&[1]); self.n_layers()];
        let mut dz_cur = dz;
        for l in (0..self.n_layers()).rev() {
            // dW_l = x_l^T @ dz; db_l = column sums of dz.
            dws[l] = acts[l].transpose().matmul(&dz_cur);
            let n = dz_cur.shape()[1];
            let mut db = vec![0.0f32; n];
            for r in 0..batch {
                for j in 0..n {
                    db[j] += dz_cur.at2(r, j);
                }
            }
            dbs[l] = Tensor::from_vec(&[n], db);
            if l == 0 {
                // dx0 = dz @ W_0^T — its first emb_dim columns flow back.
                let dx0 = dz_cur.matmul(&self.weights[0].transpose());
                let mut demb = vec![0.0f32; batch * self.emb_dim];
                for r in 0..batch {
                    demb[r * self.emb_dim..(r + 1) * self.emb_dim]
                        .copy_from_slice(&dx0.row(r)[..self.emb_dim]);
                }
                return (
                    loss,
                    DenseGrads {
                        weights: dws,
                        biases: dbs,
                        emb: Tensor::from_vec(&[batch, self.emb_dim], demb),
                    },
                );
            }
            // dx_l = dz @ W_l^T, then through ReLU of layer l-1.
            let mut dx = dz_cur.matmul(&self.weights[l].transpose());
            let z_prev = &zs[l - 1];
            for r in 0..batch {
                for j in 0..dx.shape()[1] {
                    if z_prev.at2(r, j) <= 0.0 {
                        *dx.at2_mut(r, j) = 0.0;
                    }
                }
            }
            dz_cur = dx;
        }
        unreachable!("loop returns at l == 0");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DenseModel {
        let mut rng = Rng::new(1);
        DenseModel::new(&[12, 16, 8, 1], 8, 4, &mut rng)
    }

    fn batch(rng: &mut Rng, b: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let emb = rng.normal_vec(b * 8);
        let nid = rng.normal_vec(b * 4);
        let labels = (0..b).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        (emb, nid, labels)
    }

    #[test]
    fn forward_outputs_probabilities() {
        let m = model();
        let mut rng = Rng::new(2);
        let (emb, nid, _) = batch(&mut rng, 6);
        let probs = m.forward(&emb, &nid, 6);
        assert_eq!(probs.len(), 6);
        assert!(probs.iter().all(|&p| p > 0.0 && p < 1.0));
    }

    #[test]
    fn params_flat_roundtrip() {
        let mut m = model();
        let flat = m.params_flat();
        assert_eq!(flat.len(), m.param_count());
        let mut m2 = model();
        m2.set_params_flat(&flat);
        assert_eq!(m2.params_flat(), flat);
        let mut rng = Rng::new(3);
        let (emb, nid, _) = batch(&mut rng, 4);
        assert_eq!(m.forward(&emb, &nid, 4), m2.forward(&emb, &nid, 4));
    }

    #[test]
    fn gradients_match_numerical() {
        let m = model();
        let mut rng = Rng::new(4);
        let (emb, nid, labels) = batch(&mut rng, 4);
        let (_, grads) = m.train_step(&emb, &nid, &labels, 4);
        let eps = 1e-3;

        // Check a few weight coords numerically.
        for (l, i, j) in [(0usize, 0usize, 0usize), (1, 3, 2), (2, 5, 0)] {
            let mut mp = m.clone();
            *mp.weights[l].at2_mut(i, j) += eps;
            let (lp, _) = mp.train_step(&emb, &nid, &labels, 4);
            let mut mm = m.clone();
            *mm.weights[l].at2_mut(i, j) -= eps;
            let (lm, _) = mm.train_step(&emb, &nid, &labels, 4);
            let num = (lp - lm) / (2.0 * eps);
            let ana = grads.weights[l].at2(i, j);
            assert!((num - ana).abs() < 2e-2 * (1.0 + num.abs()), "l={l}: {num} vs {ana}");
        }

        // Check embedding grads numerically.
        for idx in [0usize, 7, 15] {
            let mut ep = emb.clone();
            ep[idx] += eps;
            let (lp, _) = m.train_step(&ep, &nid, &labels, 4);
            let mut em = emb.clone();
            em[idx] -= eps;
            let (lm, _) = m.train_step(&em, &nid, &labels, 4);
            let num = (lp - lm) / (2.0 * eps);
            let ana = grads.emb.data()[idx];
            assert!((num - ana).abs() < 2e-2 * (1.0 + num.abs()), "emb[{idx}]: {num} vs {ana}");
        }
    }

    #[test]
    fn sgd_descends() {
        let mut m = model();
        let mut rng = Rng::new(5);
        let (emb, nid, labels) = batch(&mut rng, 32);
        let (l0, _) = m.train_step(&emb, &nid, &labels, 32);
        for _ in 0..30 {
            let (_, g) = m.train_step(&emb, &nid, &labels, 32);
            for (w, gw) in m.weights.iter_mut().zip(&g.weights) {
                w.axpy(-0.5, gw);
            }
            for (b, gb) in m.biases.iter_mut().zip(&g.biases) {
                b.axpy(-0.5, gb);
            }
        }
        let (l1, _) = m.train_step(&emb, &nid, &labels, 32);
        assert!(l1 < l0 * 0.8, "{l0} -> {l1}");
    }

    #[test]
    fn loss_matches_manual_bce() {
        // Single layer, known weights -> closed-form check.
        let mut rng = Rng::new(6);
        let mut m = DenseModel::new(&[2, 2, 1], 1, 1, &mut rng);
        // Make it effectively linear: big hidden identity-ish isn't needed —
        // just compute expected loss via forward probabilities.
        m.weights[0] = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        m.biases[0] = Tensor::from_vec(&[2], vec![0.0, 0.0]);
        m.weights[1] = Tensor::from_vec(&[2, 1], vec![1.0, -1.0]);
        m.biases[1] = Tensor::from_vec(&[1], vec![0.5]);
        let emb = vec![1.0, 2.0];
        let nid = vec![3.0, -1.0];
        let labels = vec![1.0, 0.0];
        let (loss, _) = m.train_step(&emb, &nid, &labels, 2);
        // Row 0: x=[1,3] relu->[1,3], z = 1 - 3 + 0.5 = -1.5, y=1.
        // Row 1: x=[2,-1] relu->[2,0], z = 2 - 0 + 0.5 = 2.5, y=0.
        let bce = |z: f32, y: f32| z.max(0.0) - z * y + (-z.abs()).exp().ln_1p();
        let want = (bce(-1.5, 1.0) + bce(2.5, 0.0)) / 2.0;
        assert!((loss - want).abs() < 1e-6, "{loss} vs {want}");
    }
}
