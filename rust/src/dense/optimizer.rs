//! Dense-side optimizer over the flattened parameter vector (Alg. 2's Ω^nn).
//!
//! Runs on each NN worker after the gradient AllReduce; since all workers see
//! the identical mean gradient and share the init, their parameter copies
//! stay bit-identical without further synchronization.

/// Dense optimizer flavors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DenseOptimizerKind {
    Sgd,
    /// SGD + classical momentum.
    Momentum,
    Adam,
}

/// Optimizer with state sized to the flat parameter vector.
#[derive(Clone)]
pub struct DenseOptimizer {
    kind: DenseOptimizerKind,
    lr: f32,
    momentum: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl DenseOptimizer {
    pub fn new(kind: DenseOptimizerKind, lr: f32, n_params: usize) -> Self {
        let state = match kind {
            DenseOptimizerKind::Sgd => 0,
            DenseOptimizerKind::Momentum => n_params,
            DenseOptimizerKind::Adam => n_params,
        };
        Self {
            kind,
            lr,
            momentum: 0.9,
            m: vec![0.0; state],
            v: if kind == DenseOptimizerKind::Adam { vec![0.0; n_params] } else { Vec::new() },
            t: 0,
        }
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Stable code of this optimizer's kind (0 = SGD, 1 = momentum,
    /// 2 = Adam) — the value checkpoint manifests record.
    pub fn kind_code(&self) -> u64 {
        match self.kind {
            DenseOptimizerKind::Sgd => 0,
            DenseOptimizerKind::Momentum => 1,
            DenseOptimizerKind::Adam => 2,
        }
    }

    /// The optimizer kind for `kind_code` values (checkpoint restore).
    pub fn kind_from_code(code: u64) -> Option<DenseOptimizerKind> {
        Some(match code {
            0 => DenseOptimizerKind::Sgd,
            1 => DenseOptimizerKind::Momentum,
            2 => DenseOptimizerKind::Adam,
            _ => return None,
        })
    }

    /// Checkpointable state: `(step counter, first moments, second moments)`
    /// — with `params`, everything a resumed replica needs to continue
    /// bit-identically.
    pub fn state(&self) -> (u64, &[f32], &[f32]) {
        (self.t, &self.m, &self.v)
    }

    /// Restore state captured by [`DenseOptimizer::state`]. Shapes must
    /// match this optimizer's kind and parameter count exactly.
    pub fn restore_state(&mut self, t: u64, m: &[f32], v: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            m.len() == self.m.len(),
            "optimizer m state has {} entries, this optimizer needs {}",
            m.len(),
            self.m.len()
        );
        anyhow::ensure!(
            v.len() == self.v.len(),
            "optimizer v state has {} entries, this optimizer needs {}",
            v.len(),
            self.v.len()
        );
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        self.t = t;
        Ok(())
    }

    /// `params -= update(grad)` in place.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        self.t += 1;
        match self.kind {
            DenseOptimizerKind::Sgd => {
                for (p, g) in params.iter_mut().zip(grad) {
                    *p -= self.lr * g;
                }
            }
            DenseOptimizerKind::Momentum => {
                for ((p, g), m) in params.iter_mut().zip(grad).zip(self.m.iter_mut()) {
                    *m = self.momentum * *m + g;
                    *p -= self.lr * *m;
                }
            }
            DenseOptimizerKind::Adam => {
                const B1: f32 = 0.9;
                const B2: f32 = 0.999;
                let bc1 = 1.0 - B1.powi(self.t as i32);
                let bc2 = 1.0 - B2.powi(self.t as i32);
                for i in 0..params.len() {
                    self.m[i] = B1 * self.m[i] + (1.0 - B1) * grad[i];
                    self.v[i] = B2 * self.v[i] + (1.0 - B2) * grad[i] * grad[i];
                    params[i] -=
                        self.lr * (self.m[i] / bc1) / ((self.v[i] / bc2).sqrt() + 1e-8);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimize(kind: DenseOptimizerKind, lr: f32, steps: usize) -> f32 {
        // f(p) = sum (p_i - i)^2 over 4 coords.
        let mut opt = DenseOptimizer::new(kind, lr, 4);
        let mut p = vec![0.0f32; 4];
        for _ in 0..steps {
            let g: Vec<f32> = p.iter().enumerate().map(|(i, &x)| 2.0 * (x - i as f32)).collect();
            opt.step(&mut p, &g);
        }
        p.iter().enumerate().map(|(i, &x)| (x - i as f32).powi(2)).sum()
    }

    #[test]
    fn all_kinds_minimize_quadratic() {
        assert!(minimize(DenseOptimizerKind::Sgd, 0.1, 100) < 1e-3);
        assert!(minimize(DenseOptimizerKind::Momentum, 0.02, 100) < 1e-3);
        assert!(minimize(DenseOptimizerKind::Adam, 0.3, 300) < 1e-2);
    }

    #[test]
    fn sgd_step_is_exact() {
        let mut opt = DenseOptimizer::new(DenseOptimizerKind::Sgd, 0.5, 2);
        let mut p = vec![1.0, -1.0];
        opt.step(&mut p, &[2.0, -4.0]);
        assert_eq!(p, vec![0.0, 1.0]);
    }

    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        // Run 10 steps, snapshot, run 10 more; a fresh optimizer restored
        // from the snapshot must finish the last 10 identically.
        let grads: Vec<Vec<f32>> =
            (0..20).map(|i| vec![(i as f32).sin(), 0.5, -0.25]).collect();
        for kind in [
            DenseOptimizerKind::Sgd,
            DenseOptimizerKind::Momentum,
            DenseOptimizerKind::Adam,
        ] {
            let mut a = DenseOptimizer::new(kind, 0.1, 3);
            let mut pa = vec![0.0f32; 3];
            for g in &grads[..10] {
                a.step(&mut pa, g);
            }
            let (t, m, v) = a.state();
            let (t, m, v) = (t, m.to_vec(), v.to_vec());
            let mid = pa.clone();
            for g in &grads[10..] {
                a.step(&mut pa, g);
            }

            let mut b = DenseOptimizer::new(kind, 0.1, 3);
            b.restore_state(t, &m, &v).unwrap();
            let mut pb = mid;
            for g in &grads[10..] {
                b.step(&mut pb, g);
            }
            assert_eq!(pa, pb, "{kind:?} resume diverged");
            assert_eq!(DenseOptimizer::kind_from_code(b.kind_code()), Some(kind));
        }
        assert_eq!(DenseOptimizer::kind_from_code(9), None);
    }

    #[test]
    fn restore_state_rejects_shape_mismatch() {
        let mut opt = DenseOptimizer::new(DenseOptimizerKind::Adam, 0.1, 3);
        assert!(opt.restore_state(1, &[0.0; 2], &[0.0; 3]).is_err());
        assert!(opt.restore_state(1, &[0.0; 3], &[0.0; 4]).is_err());
        // SGD has no moment state at all.
        let mut sgd = DenseOptimizer::new(DenseOptimizerKind::Sgd, 0.1, 3);
        assert!(sgd.restore_state(1, &[0.0; 3], &[]).is_err());
        sgd.restore_state(5, &[], &[]).unwrap();
    }

    #[test]
    fn identical_inputs_keep_replicas_identical() {
        // The hybrid trainer's invariant: same grads => same params.
        let mut a = DenseOptimizer::new(DenseOptimizerKind::Momentum, 0.1, 3);
        let mut b = DenseOptimizer::new(DenseOptimizerKind::Momentum, 0.1, 3);
        let mut pa = vec![0.5, 0.5, 0.5];
        let mut pb = pa.clone();
        for i in 0..50 {
            let g = vec![(i as f32).sin(), 0.2, -0.1];
            a.step(&mut pa, &g);
            b.step(&mut pb, &g);
        }
        assert_eq!(pa, pb);
    }
}
