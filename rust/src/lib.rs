//! # Persia — hybrid distributed training for 100-trillion-parameter recommenders
//!
//! From-scratch reproduction of *"Persia: An Open, Hybrid System Scaling Deep
//! Learning-based Recommenders up to 100 Trillion Parameters"* (KDD 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: data loader,
//!   embedding workers, sharded embedding parameter server with an array-list
//!   LRU cache, NN workers, zero-copy tensor RPC, index/value compression,
//!   bucketed ring AllReduce, and the sync/async/**hybrid** training
//!   algorithms with bounded staleness.
//! * **L2/L1 (build-time Python)** — the dense tower fwd/bwd (JAX) built on
//!   Pallas kernels, AOT-lowered to HLO text in `artifacts/` and executed
//!   here via the PJRT CPU client ([`runtime`]). Python never runs on the
//!   training hot path.
//!
//! # Deployed topology (the three service tiers)
//!
//! Every stateful role of the paper's Fig. 2 runs either in-process (the
//! simulated cluster) or as its own OS process, with numerical parity
//! between the two proven by the loopback test matrix:
//!
//! ```text
//!   persia serve-ps (×N)  ◀──GET/PUT──  persia serve-embedding-worker (×M)
//!   node-range shards,                  data-loader streams + pipelined
//!   SNAPSHOT/RESTORE                    prefetcher (NEXT_BATCH/PUSH_GRADS)
//!                                            ▲
//!                                            │ round-robin rank % M
//!   persia train-worker (×K)  ◀──ring──▶  … peers
//!   one dense rank per process, TCP ring AllReduce
//! ```
//!
//! * **Embedding PS tier** — `persia serve-ps [--node-range]` serves a
//!   (slice of a) PS over the [`service`] wire protocol; trainers and
//!   embedding workers reach it through the [`service::PsBackend`] trait
//!   (in-process [`embedding::EmbeddingPs`], single-server
//!   [`service::RemotePs`], or scatter-gathered
//!   [`service::ShardedRemotePs`]), with the §4.2.3 index/value compression
//!   on the wire and the §4.2.4 SNAPSHOT/RESTORE + reconnect recovery
//!   drill.
//! * **Embedding-worker tier** — `persia serve-embedding-worker` promotes
//!   the [`worker`] middle tier to its own process: it owns the data-loader
//!   streams of its NN ranks and runs the pipelined prefetcher
//!   ([`worker::PrefetchPipeline`]) so PS latency hides behind dense
//!   compute. Trainers reach it via `--embedding-workers` through the
//!   [`worker::EmbComm`] seam ([`service::RemoteEmbTier`]).
//! * **NN-worker tier** — `persia train-worker --rank R --world K` runs one
//!   dense rank per process, joined by a rank-0 TCP rendezvous, with the
//!   §4.2.3 ring AllReduce over real sockets ([`allreduce::tcp_ring`])
//!   behind the [`hybrid::DenseComm`] seam.
//!
//! Every cross-process handshake (PS INFO, embedding-worker INFO, ring
//! rendezvous) carries a config fingerprint, so a process started with
//! different numeric flags is rejected at connect time instead of silently
//! diverging. Deterministic mode makes multi-process deployments
//! bit-reproducible (`rust/tests/integration_service.rs`,
//! `integration_sharded.rs`, `integration_multiproc.rs`,
//! `integration_embedding_worker.rs`).
//!
//! Entry points: [`hybrid::Trainer`] for end-to-end training,
//! [`config::BenchPreset`] for the paper's Table-1 benchmark presets, and the
//! `persia` binary / `examples/` for runnable drivers. See `ARCHITECTURE.md`
//! for the full paper-component → module/binary map.

#[warn(missing_docs)]
pub mod allreduce;
pub mod comm;
pub mod config;
pub mod data;
pub mod dense;
#[warn(missing_docs)]
pub mod embedding;
pub mod fault;
#[warn(missing_docs)]
pub mod hybrid;
pub mod metrics;
#[warn(missing_docs)]
pub mod recovery;
pub mod runtime;
#[warn(missing_docs)]
pub mod service;
pub mod sim;
pub mod tensor;
pub mod util;
#[warn(missing_docs)]
pub mod worker;
