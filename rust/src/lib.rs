//! # Persia — hybrid distributed training for 100-trillion-parameter recommenders
//!
//! From-scratch reproduction of *"Persia: An Open, Hybrid System Scaling Deep
//! Learning-based Recommenders up to 100 Trillion Parameters"* (KDD 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: data loader,
//!   embedding workers, sharded embedding parameter server with an array-list
//!   LRU cache, NN workers, zero-copy tensor RPC, index/value compression,
//!   bucketed ring AllReduce, and the sync/async/**hybrid** training
//!   algorithms with bounded staleness.
//! * **L2/L1 (build-time Python)** — the dense tower fwd/bwd (JAX) built on
//!   Pallas kernels, AOT-lowered to HLO text in `artifacts/` and executed
//!   here via the PJRT CPU client ([`runtime`]). Python never runs on the
//!   training hot path.
//!
//! # Service mode (deployed topology)
//!
//! Besides the in-process simulated cluster, the embedding PS runs as one
//! or many standalone TCP server processes ([`service`]): embedding workers
//! reach it through the [`service::PsBackend`] trait — in-process
//! ([`embedding::EmbeddingPs`]), one server ([`service::RemotePs`] →
//! [`service::PsServer`]), or N shard processes each owning a node range
//! ([`service::ShardedRemotePs`], scatter-gathered with the servers' own
//! global hash) — with batched deduplicated get/put and the §4.2.3
//! index/value compression on the wire. `persia serve-ps [--node-range]`
//! starts a (slice of a) server, `persia train --remote-ps <addr,...>`
//! trains against the fleet, wire-level SNAPSHOT/RESTORE plus client
//! reconnect implement the §4.2.4 kill/restore recovery drill, and the
//! loopback test matrix (`rust/tests/integration_service.rs`,
//! `rust/tests/integration_sharded.rs`) proves remote training is
//! numerically identical to in-process training in every mode.
//!
//! The NN workers deploy as processes too: `persia train-worker --rank R
//! --world N` runs one dense rank per process, joined by a rank-0 TCP
//! rendezvous with a config-fingerprint handshake, and the §4.2.3 ring
//! AllReduce crosses real sockets ([`allreduce::tcp_ring`]) behind the
//! [`hybrid::DenseComm`] seam — with deterministic FullSync proven
//! equivalent to the threaded run (`rust/tests/integration_multiproc.rs`).
//!
//! Entry points: [`hybrid::Trainer`] for end-to-end training,
//! [`config::BenchPreset`] for the paper's Table-1 benchmark presets, and the
//! `persia` binary / `examples/` for runnable drivers.

pub mod allreduce;
pub mod comm;
pub mod config;
pub mod data;
pub mod dense;
pub mod embedding;
pub mod fault;
pub mod hybrid;
pub mod metrics;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod tensor;
pub mod util;
pub mod worker;
