//! Analytic cluster model: throughput projection for scales beyond this
//! machine's RAM (the 100-trillion-parameter capacity runs of Fig. 9) and
//! the roofline notes used by EXPERIMENTS.md §Perf.
//!
//! The projection composes per-component costs that the *measured* runs
//! calibrate (rows/s a PS shard serves, samples/s one NN worker trains,
//! bytes each phase moves) with the paper's cluster geometry (8×8 A100 NN
//! workers, 100 embedding workers, 30 PS nodes, 100 Gbps).

use crate::config::{ModelConfig, NetModelConfig, TrainMode};

/// Calibrated per-component costs (from measured small-scale runs).
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Seconds one NN worker spends in fwd+bwd for one batch.
    pub t_train: f64,
    /// Rows/second one PS node serves (get+put combined).
    pub ps_rows_per_sec: f64,
    /// Embedding-worker pooling overhead per row (seconds).
    pub pool_row_secs: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        // Conservative CPU-measured defaults; benches overwrite these with
        // live measurements before projecting.
        Self { t_train: 5e-3, ps_rows_per_sec: 2.0e6, pool_row_secs: 40e-9 }
    }
}

/// Cluster geometry for a projection.
#[derive(Clone, Copy, Debug)]
pub struct ClusterSpec {
    pub n_nn_workers: usize,
    pub n_emb_workers: usize,
    pub n_ps_nodes: usize,
    pub net: NetModelConfig,
}

impl ClusterSpec {
    /// The paper's Google-cloud capacity cluster (§6, cluster setup).
    pub fn paper_cloud() -> Self {
        Self {
            n_nn_workers: 64, // 8 x a2-highgpu-8g
            n_emb_workers: 100,
            n_ps_nodes: 30,
            net: NetModelConfig::paper_like(),
        }
    }
}

/// Projected throughput (samples/sec) for a mode at a given model scale.
///
/// The embedding-side work per sample is independent of *virtual* table size
/// (hash + row fetch), which is why the paper's Fig. 9-left curve is flat;
/// what separates the modes is how much of the per-step time the pipeline
/// hides (Fig. 3's algebra, same as the trainer's simulated clock).
pub fn project_throughput(
    model: &ModelConfig,
    spec: &ClusterSpec,
    cal: &Calibration,
    mode: TrainMode,
    batch: usize,
) -> f64 {
    let rows_per_sample = (model.n_groups * model.ids_per_group) as f64;
    let bytes_per_row = model.emb_dim_per_group as f64 * 4.0;
    let act_bytes = (batch * model.emb_dim()) as f64 * 4.0;

    // Embedding preparation time per batch (PS fetch, pooling, transfer).
    let ps_rows_cap = spec.n_ps_nodes as f64 * cal.ps_rows_per_sec;
    // All NN workers stream concurrently; each sees 1/K of PS capacity.
    let rows_per_batch = rows_per_sample * batch as f64;
    let t_ps = rows_per_batch / (ps_rows_cap / spec.n_nn_workers as f64);
    let t_pool = rows_per_batch * cal.pool_row_secs;
    let t_xfer = if spec.net.cpu_gpu_bw > 0.0 {
        (rows_per_batch * bytes_per_row + 2.0 * act_bytes) / spec.net.cpu_gpu_bw
            + 2.0 * spec.net.latency_s
    } else {
        0.0
    };
    let t_prep = t_ps + t_pool + t_xfer;

    // Dense AllReduce per step: ring, 2(K-1)/K of the dense params.
    let dense_bytes = model.dense_param_count() as f64 * 4.0;
    let k = spec.n_nn_workers as f64;
    let t_ar = if spec.net.gpu_gpu_bw > 0.0 && spec.n_nn_workers > 1 {
        2.0 * (k - 1.0) / k * dense_bytes / spec.net.gpu_gpu_bw
            + 2.0 * (k - 1.0) * spec.net.latency_s
    } else {
        0.0
    };

    let t_train = cal.t_train;
    let step = match mode {
        TrainMode::FullSync => t_prep + t_train + t_ar + t_prep * 0.5,
        TrainMode::HybridRaw => (t_train + t_ar).max(t_prep),
        TrainMode::Hybrid => {
            let exposed_ar = (t_ar - t_train * 2.0 / 3.0).max(0.0);
            (t_train + exposed_ar).max(t_prep)
        }
        TrainMode::FullAsync => t_train.max(t_prep * 0.8),
    };
    batch as f64 * spec.n_nn_workers as f64 / step
}

/// Roofline-style note for the L1 kernel at paper scale (documentation aid).
pub fn mxu_utilization_estimate(
    block_m: usize,
    block_n: usize,
    block_k: usize,
) -> f64 {
    // An MXU pass is a 128x128x128 systolic tile; utilization is the filled
    // fraction of the tile in each dimension.
    let f = |b: usize| (b.min(128) as f64) / 128.0;
    f(block_m) * f(block_n) * f(block_k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pooling;

    fn model() -> ModelConfig {
        ModelConfig {
            artifact_preset: "paper".into(),
            n_groups: 8,
            emb_dim_per_group: 16,
            nid_dim: 64,
            hidden: vec![4096, 2048, 1024, 512, 256],
            ids_per_group: 8,
            pooling: Pooling::Sum,
        }
    }

    #[test]
    fn throughput_independent_of_virtual_scale() {
        // The projection takes no table-size input at all — flatness of
        // Fig. 9-left is structural. This test documents that invariant.
        let t = project_throughput(
            &model(),
            &ClusterSpec::paper_cloud(),
            &Calibration::default(),
            TrainMode::Hybrid,
            256,
        );
        assert!(t > 0.0);
    }

    #[test]
    fn mode_ordering_matches_paper() {
        let m = model();
        let spec = ClusterSpec::paper_cloud();
        let cal = Calibration::default();
        let thpt = |mode| project_throughput(&m, &spec, &cal, mode, 256);
        let sync = thpt(TrainMode::FullSync);
        let raw = thpt(TrainMode::HybridRaw);
        let hybrid = thpt(TrainMode::Hybrid);
        let asynch = thpt(TrainMode::FullAsync);
        // Paper Fig. 9-right: async >= hybrid > raw-hybrid > sync, with
        // hybrid/sync around 2.6x and async/hybrid around 1.2x.
        assert!(sync < raw && raw <= hybrid && hybrid <= asynch, "{sync} {raw} {hybrid} {asynch}");
        let ratio = hybrid / sync;
        assert!(ratio > 1.5 && ratio < 6.0, "hybrid/sync={ratio}");
        let ratio2 = asynch / hybrid;
        assert!((1.0..2.0).contains(&ratio2), "async/hybrid={ratio2}");
    }

    #[test]
    fn mxu_estimate_bounds() {
        assert_eq!(mxu_utilization_estimate(128, 128, 128), 1.0);
        assert!((mxu_utilization_estimate(64, 128, 128) - 0.5).abs() < 1e-9);
        assert!(mxu_utilization_estimate(8, 8, 8) < 0.001);
    }
}
