//! The tiered-storage acceptance test: a training run whose embedding table
//! lives mostly on disk is **bitwise identical** to the all-hot run.
//!
//! Demotion and promotion move exact row bytes (embedding ⊕ optimizer
//! state) between tiers and never re-materialize a resident row, so in
//! deterministic FullSync the only observable difference between an all-hot
//! PS and a tiered PS with a tiny hot budget is *where* rows wait between
//! touches. These tests pin that equivalence end to end through the real
//! trainer — loss curve, final AUC, and final dense parameters — while the
//! tiered run's table is required to overflow its hot budget many times
//! over.

use std::sync::Arc;

use persia::config::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::embedding::{EmbeddingPs, StoreConfig};
use persia::hybrid::Trainer;

fn trainer(seed: u64) -> Trainer {
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 4,
        emb_dim_per_group: 8,
        nid_dim: 8,
        hidden: vec![32, 16],
        ids_per_group: 4,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 2000,
        shard_capacity: 8192,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster =
        ClusterConfig { n_nn_workers: 1, n_emb_workers: 2, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: 32,
        lr: 0.1,
        staleness_bound: 4,
        steps: 120,
        eval_every: 120,
        seed,
        use_pjrt: false,
        compress: true,
    };
    let dataset = SyntheticDataset::new(&model, 2000, 1.05, seed);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    t.eval_rows = 1024;
    t
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("persia_it_tiered_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn tiered_run_is_bitwise_identical_to_all_hot() {
    let seed = 21;

    // Baseline determinism guard: two all-hot runs must agree exactly, or
    // any tiered mismatch below would be unattributable.
    let hot_a = trainer(seed).run_rust().unwrap();
    let hot_b = trainer(seed).run_rust().unwrap();
    assert_eq!(hot_a.tracker.losses, hot_b.tracker.losses, "FullSync baseline not deterministic");
    assert_eq!(hot_a.final_params, hot_b.final_params);

    // Tiered run against an explicit PS backend so the tiers are
    // inspectable afterwards: 64 hot rows per shard over 4 shards = 256
    // rows of hot budget, against a working set in the thousands.
    let dir = tmp_dir("parity");
    let t = trainer(seed);
    let store = StoreConfig::Tiered {
        hot_capacity: 64,
        cold_dir: dir.clone(),
        admit_threshold: 2,
    };
    let ps = Arc::new(
        EmbeddingPs::new_with_store(&t.emb_cfg, t.model.emb_dim_per_group, t.train.seed, &store)
            .unwrap(),
    );
    let mut t = t;
    t.ps_backend = Some(ps.clone());
    let tiered = t.run_rust().unwrap();

    // Bitwise parity: same losses at every step, same final AUC, same
    // final dense parameters. Placement changed; numerics did not.
    assert_eq!(
        hot_a.tracker.losses, tiered.tracker.losses,
        "tiered loss curve diverged from all-hot"
    );
    assert_eq!(hot_a.final_params, tiered.final_params, "final dense params diverged");
    let (auc_hot, auc_tiered) =
        (hot_a.report.final_auc.unwrap(), tiered.report.final_auc.unwrap());
    assert!(
        (auc_hot - auc_tiered).abs() <= 1e-6,
        "AUC diverged: all-hot {auc_hot} vs tiered {auc_tiered}"
    );

    // The equivalence must have been earned: the table overflowed the hot
    // budget many times over, with real demotion/promotion traffic.
    let hot_budget = 4 * 64; // shards × hot_capacity
    let total = ps.total_rows();
    assert!(
        total >= 8 * hot_budget,
        "working set did not stress the tiers: {total} rows vs {hot_budget} hot budget"
    );
    assert!(ps.cold_rows() > 0, "no rows resident in the cold tier");
    let tc = ps.tier_counters();
    assert!(tc.demotions > 0, "no demotions — hot tier never overflowed");
    assert!(tc.promotions > 0, "no promotions — cold rows never came back");
    assert!(tc.cold_hits > 0, "no cold hits recorded");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trainer_store_config_builds_the_tiered_ps() {
    // Same parity claim through the `Trainer::store` field (the
    // `--cold-dir`/`--hot-capacity` CLI path) instead of an explicit
    // backend: the trainer constructs the tiered in-process PS itself.
    let seed = 23;
    let hot = trainer(seed).run_rust().unwrap();

    let dir = tmp_dir("storecfg");
    let mut t = trainer(seed);
    t.store = StoreConfig::Tiered {
        hot_capacity: 64,
        cold_dir: dir.clone(),
        admit_threshold: 2,
    };
    let tiered = t.run_rust().unwrap();
    assert_eq!(hot.tracker.losses, tiered.tracker.losses);
    assert_eq!(hot.final_params, tiered.final_params);

    // The run really went through the cold files: one per (node, shard),
    // each grown past its 24-byte header by demoted rows.
    let mut cold_files = 0;
    for node in 0..2 {
        for shard in 0..2 {
            let path = dir.join(format!("cold_node{node}_shard{shard}.bin"));
            assert!(path.exists(), "missing cold file {}", path.display());
            assert!(
                std::fs::metadata(&path).unwrap().len() > 24,
                "cold file {} never received a row",
                path.display()
            );
            cold_files += 1;
        }
    }
    assert_eq!(cold_files, 4);
    std::fs::remove_dir_all(&dir).ok();
}
