//! The ISSUE-9 acceptance drills: live PS resharding against real `persia`
//! child processes.
//!
//! * **Happy path**: a 2-shard deployment plus one `--join` spare is grown
//!   to 3 shards mid-train — the trainer's reshard probe detects the
//!   imbalance, streams the hot shard's tail nodes to the spare behind the
//!   PREPARE/MIGRATE/COMMIT barrier, and the run finishes with every loss
//!   and the final AUC within 1e-6 of an unresharded reference (the run is
//!   deterministic FullSync, so the migration must be *bitwise* invisible:
//!   zero lost updates).
//! * **Source SIGKILL mid-copy**: the shard donating nodes dies while
//!   streaming. The coordinator aborts, the old routing epoch keeps
//!   serving, the victim restarts from its committed epoch + the put-replay
//!   log, and training still completes to ≤1e-6 parity.
//! * **Destination SIGKILL mid-copy**: the `--join` spare dies while
//!   receiving. The reshard rolls back — no ROUTING commit, no orphaned
//!   node range — and the untouched 2-shard layout carries the run to
//!   ≤1e-6 parity.
//!
//! The copy window is stretched with the `PERSIA_MIGRATE_DELAY_MS` test
//! hook so the SIGKILLs land mid-migration deterministically.

use std::io::BufRead as _;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, ExitStatus, Stdio};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use persia::config::{
    BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;
use persia::service::reshard::load_routing;

const PRESET: &str = "taobao";
const DENSE: &str = "tiny";
const CAPACITY: &str = "65536"; // ample: no LRU evictions, exact replay
const SEED: &str = "42";
const BATCH: &str = "16";
/// A finer node grid than the preset default so the planner has split
/// points: ps0 serves 0..4, ps1 serves 4..6 — with roughly uniform
/// per-node traffic (ShuffledUniform) the per-process imbalance is
/// (4/6)/(1/2) ≈ 1.33, comfortably above the 1.1 drill threshold.
const N_NODES: usize = 6;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("persia_reshard_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Threaded in-process replica of the deployment's config — the unresharded
/// reference. Threads ≡ processes and local PS ≡ remote PS are both
/// already-proven bitwise properties of this configuration, so the only
/// degree of freedom left for the drills to test is the resharding itself.
fn baseline_trainer(steps: usize) -> Trainer {
    let preset = BenchPreset::by_name(PRESET).unwrap();
    let model = preset.model(DENSE);
    let mut emb_cfg = preset.embedding(&model, CAPACITY.parse().unwrap());
    emb_cfg.n_nodes = N_NODES;
    let rows = preset.embedding(&model, 1).rows_per_group;
    let cluster = ClusterConfig {
        n_nn_workers: 1,
        n_emb_workers: 1,
        net: NetModelConfig::disabled(),
    };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: BATCH.parse().unwrap(),
        lr: 0.05,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: SEED.parse().unwrap(),
        use_pjrt: false,
        compress: false,
    };
    let dataset =
        SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED.parse().unwrap());
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    t
}

/// A spawned `persia` child with stdout+stderr streamed into a line buffer
/// (so pipes never fill) and kill-on-drop reaping.
struct Proc {
    child: Child,
    lines: Arc<Mutex<Vec<String>>>,
    readers: Vec<JoinHandle<()>>,
}

impl Proc {
    fn spawn(args: &[String]) -> Proc {
        Self::spawn_env(args, &[])
    }

    fn spawn_env(args: &[String], env: &[(&str, &str)]) -> Proc {
        let exe = env!("CARGO_BIN_EXE_persia");
        let mut cmd = Command::new(exe);
        cmd.args(args).stdout(Stdio::piped()).stderr(Stdio::piped());
        for (k, v) in env {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().expect("spawn persia child");
        let lines = Arc::new(Mutex::new(Vec::new()));
        let mut readers = Vec::new();
        let stdout = child.stdout.take().expect("stdout piped");
        let stderr = child.stderr.take().expect("stderr piped");
        for reader in [Box::new(stdout) as Box<dyn std::io::Read + Send>, Box::new(stderr)] {
            let lines = lines.clone();
            readers.push(std::thread::spawn(move || {
                let buf = std::io::BufReader::new(reader);
                for line in buf.lines() {
                    match line {
                        Ok(l) => lines.lock().unwrap().push(l),
                        Err(_) => break,
                    }
                }
            }));
        }
        Proc { child, lines, readers }
    }

    fn has_line(&self, pat: &str) -> bool {
        self.lines.lock().unwrap().iter().any(|l| l.contains(pat))
    }

    fn wait_for_line(&mut self, pat: &str, timeout: Duration) -> Option<String> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(l) =
                self.lines.lock().unwrap().iter().find(|l| l.contains(pat)).cloned()
            {
                return Some(l);
            }
            if Instant::now() >= deadline {
                return None;
            }
            if let Ok(Some(_)) = self.child.try_wait() {
                std::thread::sleep(Duration::from_millis(100));
                return self.lines.lock().unwrap().iter().find(|l| l.contains(pat)).cloned();
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    fn wait_timeout(&mut self, timeout: Duration) -> Option<ExitStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.child.try_wait().expect("try_wait") {
                Some(status) => return Some(status),
                None if Instant::now() >= deadline => return None,
                None => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    fn output_snapshot(&self) -> String {
        self.lines.lock().unwrap().join("\n")
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        self.kill();
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
    }
}

fn strs(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

/// The numeric flags every process of a deployment shares (they ride in the
/// config fingerprint, so all processes must agree).
fn shared_flags(steps: usize) -> Vec<String> {
    strs(&[
        "--preset", PRESET, "--dense", DENSE, "--engine", "rust", "--mode", "sync",
        "--deterministic", "true", "--shard-capacity", CAPACITY, "--seed", SEED,
        "--batch", BATCH, "--lr", "0.05", "--tau", "4", "--netsim", "false",
        "--compress", "false", "--emb-workers", "1", "--nn-workers", "1",
        "--nodes", "6",
    ])
    .into_iter()
    .chain([
        "--steps".to_string(),
        steps.to_string(),
        "--eval-every".to_string(),
        steps.to_string(),
    ])
    .collect()
}

/// Spawn `persia serve-ps` on `addr` (a `--node-range` owner when `range`
/// is `Some`, a `--join` spare otherwise) and wait for its listening line,
/// retrying the spawn (rebinding a just-released port can race the old
/// socket's teardown — the restart half of the kill drills).
fn spawn_ps(
    addr: &str,
    range: Option<&str>,
    steps: usize,
    ckpt_dir: &Path,
    env: &[(&str, &str)],
) -> (Proc, String) {
    spawn_ps_extra(addr, range, steps, ckpt_dir, env, &[])
}

/// [`spawn_ps`] with extra flags appended — the cache drill runs its fleet
/// under `--optimizer sgd`, which rides in the embedding config every
/// process must agree on.
fn spawn_ps_extra(
    addr: &str,
    range: Option<&str>,
    steps: usize,
    ckpt_dir: &Path,
    env: &[(&str, &str)],
    extra: &[&str],
) -> (Proc, String) {
    for attempt in 0..40u64 {
        let mut args = strs(&["serve-ps", "--addr"]);
        args.push(addr.to_string());
        match range {
            Some(r) => {
                args.push("--node-range".to_string());
                args.push(r.to_string());
            }
            None => args.extend(strs(&["--join", "true"])),
        }
        args.extend(shared_flags(steps));
        args.push("--checkpoint-dir".to_string());
        args.push(ckpt_dir.display().to_string());
        args.extend(strs(extra));
        let mut p = Proc::spawn_env(&args, env);
        if let Some(line) = p.wait_for_line("listening on ", Duration::from_secs(30)) {
            let got = line
                .split("listening on ")
                .nth(1)
                .and_then(|r| r.split_whitespace().next())
                .expect("address in listening line")
                .to_string();
            return (p, got);
        }
        drop(p);
        std::thread::sleep(Duration::from_millis(100 + 50 * attempt));
    }
    panic!("persia serve-ps would not start on {addr} ({range:?})");
}

/// `persia train` against a sharded remote PS fleet, with the reshard probe
/// armed (cadence 10, threshold 1.1, checkpoints every 5 steps so each
/// migration boundary is also a checkpoint boundary).
fn train_args(remote: &str, steps: usize, dir: &Path, extra: &[&str]) -> Vec<String> {
    let mut args = strs(&["train", "--parity-lines", "true", "--remote-ps"]);
    args.push(remote.to_string());
    args.extend(shared_flags(steps));
    args.push("--checkpoint-dir".to_string());
    args.push(dir.display().to_string());
    args.extend(strs(&[
        "--checkpoint-every", "5", "--reshard-every", "10", "--reshard-threshold", "1.1",
    ]));
    args.extend(strs(extra));
    args
}

fn parse_losses(output: &str) -> Vec<(u64, f32)> {
    let line = output
        .lines()
        .find(|l| l.starts_with("LOSSES "))
        .unwrap_or_else(|| panic!("no LOSSES line in:\n{output}"));
    line["LOSSES ".len()..]
        .split(',')
        .filter(|f| !f.is_empty())
        .map(|f| {
            let (s, l) = f.split_once(':').expect("step:loss");
            (s.parse().unwrap(), l.parse().unwrap())
        })
        .collect()
}

fn parse_parity(output: &str) -> (f32, f64) {
    let line = output
        .lines()
        .find(|l| l.starts_with("PARITY "))
        .unwrap_or_else(|| panic!("no PARITY line in:\n{output}"));
    let mut loss = f32::NAN;
    let mut auc = f64::NAN;
    for field in line["PARITY ".len()..].split_whitespace() {
        if let Some(v) = field.strip_prefix("final_loss=") {
            loss = v.parse().unwrap();
        }
        if let Some(v) = field.strip_prefix("final_auc=") {
            auc = v.parse().unwrap_or(f64::NAN);
        }
    }
    (loss, auc)
}

/// Every loss the run printed must match the unresharded reference at the
/// same step within the 1e-6 acceptance bound.
fn assert_run_matches_baseline(out: &str, baseline: &persia::hybrid::TrainOutput, what: &str) {
    let got = parse_losses(out);
    let want: Vec<(u64, f32)> = baseline.tracker.losses.clone();
    assert_eq!(got.len(), want.len(), "{what}: loss curve lengths differ");
    for (step, loss) in &got {
        let (_, ref_loss) = want
            .iter()
            .find(|(s, _)| s == step)
            .unwrap_or_else(|| panic!("{what}: reference has no step {step}"));
        assert!(
            (loss - ref_loss).abs() <= 1e-6,
            "{what}: step {step} loss {loss} vs reference {ref_loss}"
        );
    }
    let (loss, auc) = parse_parity(out);
    let base_loss = baseline.report.final_loss;
    let base_auc = baseline.report.final_auc.unwrap();
    assert!((loss - base_loss).abs() <= 1e-6, "{what}: final loss {loss} vs {base_loss}");
    assert!((auc - base_auc).abs() <= 1e-6, "{what}: final AUC {auc} vs {base_auc}");
}

/// Block until `pat` shows up on either shard's output; returns which one
/// (the planner picks the hottest shard as the migration source, which the
/// test must not hard-code).
fn wait_either(a: &Proc, b: &Proc, pat: &str, timeout: Duration) -> usize {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if a.has_line(pat) {
            return 0;
        }
        if b.has_line(pat) {
            return 1;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!(
        "neither shard printed {pat:?};\nshard 0:\n{}\nshard 1:\n{}",
        a.output_snapshot(),
        b.output_snapshot()
    );
}

/// Happy path: grow a live 2-shard deployment to 3 shards mid-train. The
/// probe at the step-10 boundary sees the ≈1.33 imbalance, migrates the hot
/// shard's tail onto the `--join` spare, commits routing epoch 1, persists
/// the ROUTING table — and the deterministic FullSync run still matches the
/// unresharded reference within 1e-6 on every loss and the final AUC
/// (i.e. the migration lost no update and corrupted no row).
#[test]
fn live_split_two_to_three_shards_matches_unresharded_reference() {
    let steps = 30;
    let dir = tmp_dir("grow");
    let baseline = baseline_trainer(steps).run_rust().unwrap();

    let (ps_a, addr_a) = spawn_ps("127.0.0.1:0", Some("0..4"), steps, &dir, &[]);
    let (ps_b, addr_b) = spawn_ps("127.0.0.1:0", Some("4..6"), steps, &dir, &[]);
    // The spare materializes the full node range but owns nothing; it must
    // be listed LAST in --remote-ps (epoch-0 routing is list-ordered).
    let (spare, addr_c) = spawn_ps("127.0.0.1:0", None, steps, &dir, &[]);
    assert!(
        spare.output_snapshot().contains("--join spare"),
        "spare did not announce itself:\n{}",
        spare.output_snapshot()
    );

    let mut tr =
        Proc::spawn(&train_args(&format!("{addr_a},{addr_b},{addr_c}"), steps, &dir, &[]));
    tr.wait_for_line("RESHARD epoch 1 committed", Duration::from_secs(240))
        .unwrap_or_else(|| panic!("no reshard committed:\n{}", tr.output_snapshot()));
    let status = tr
        .wait_timeout(Duration::from_secs(300))
        .unwrap_or_else(|| panic!("resharded run hung:\n{}", tr.output_snapshot()));
    assert!(status.success(), "resharded run failed:\n{}", tr.output_snapshot());
    let out = tr.output_snapshot();

    // The migration really streamed node state (it was not a no-op flip).
    assert!(
        ps_a.has_line("RESHARD: migrating node") || ps_b.has_line("RESHARD: migrating node"),
        "no shard streamed a node;\nshard 0:\n{}\nshard 1:\n{}",
        ps_a.output_snapshot(),
        ps_b.output_snapshot()
    );
    // The committed layout survived to disk, and the spare now owns nodes.
    let table = load_routing(&dir)
        .expect("ROUTING parses")
        .expect("commit persisted a ROUTING table");
    assert!(table.epoch >= 1, "persisted table still at epoch {}", table.epoch);
    assert!(
        table.owned_count(2) > 0,
        "spare owns nothing after the split: {:?}",
        table.owner
    );

    assert_run_matches_baseline(&out, &baseline, "live 2->3 split");

    drop(ps_a);
    drop(ps_b);
    drop(spare);
    std::fs::remove_dir_all(&dir).ok();
}

/// Chaos drill 1: SIGKILL the migration SOURCE mid-copy. The coordinator
/// must abort (old epoch keeps serving, nothing committed), the recovery
/// layer must carry the trainer over the shard restart (committed epoch +
/// put-replay), and the run must still finish at ≤1e-6 parity.
#[test]
fn sigkill_source_mid_copy_aborts_cleanly_and_training_survives() {
    let steps = 15; // one probe boundary (step 10), checkpoints at 5/10/15
    let dir = tmp_dir("killsrc");
    let baseline = baseline_trainer(steps).run_rust().unwrap();

    // Stretch each node's copy window to 1.5s so the kill lands mid-copy.
    let slow = [("PERSIA_MIGRATE_DELAY_MS", "1500")];
    let (mut ps_a, addr_a) = spawn_ps("127.0.0.1:0", Some("0..4"), steps, &dir, &slow);
    let (mut ps_b, addr_b) = spawn_ps("127.0.0.1:0", Some("4..6"), steps, &dir, &slow);
    let (spare, addr_c) = spawn_ps("127.0.0.1:0", None, steps, &dir, &[]);

    let mut tr = Proc::spawn(&train_args(
        &format!("{addr_a},{addr_b},{addr_c}"),
        steps,
        &dir,
        // The exact-recovery machinery: generous retries + put-replay log,
        // so the trainer rides out the victim's restart.
        &["--ps-replay", "true", "--ps-replay-cap", "65536", "--ps-retries", "200",
          "--ps-retry-ms", "100"],
    ));

    // SIGKILL whichever shard the planner picked as the source, mid-node.
    let which =
        wait_either(&ps_a, &ps_b, "RESHARD: migrating node", Duration::from_secs(240));
    let (victim, victim_addr, victim_range) = if which == 0 {
        (&mut ps_a, addr_a.clone(), "0..4")
    } else {
        (&mut ps_b, addr_b.clone(), "4..6")
    };
    victim.kill();
    // Let some traffic actually fail against the dead shard, then bring it
    // back on its own address from its committed epoch.
    std::thread::sleep(Duration::from_millis(400));
    let (ps_re, addr_re) = spawn_ps(&victim_addr, Some(victim_range), steps, &dir, &[]);
    assert_eq!(addr_re, victim_addr, "victim must come back on its own address");
    assert!(
        ps_re.output_snapshot().contains("from committed epoch step-"),
        "restarted source did not restore its epoch:\n{}",
        ps_re.output_snapshot()
    );

    tr.wait_for_line("RESHARD aborted", Duration::from_secs(120))
        .unwrap_or_else(|| panic!("no clean abort:\n{}", tr.output_snapshot()));
    let status = tr
        .wait_timeout(Duration::from_secs(300))
        .unwrap_or_else(|| panic!("run hung after the abort:\n{}", tr.output_snapshot()));
    assert!(status.success(), "run failed after the abort:\n{}", tr.output_snapshot());
    let out = tr.output_snapshot();

    // Nothing was committed: no routing flip, no persisted table.
    assert!(!out.contains("RESHARD epoch"), "a kill mid-copy must not commit:\n{out}");
    assert!(
        load_routing(&dir).expect("readable dir").is_none(),
        "aborted reshard persisted a ROUTING table"
    );

    assert_run_matches_baseline(&out, &baseline, "source-kill drill");

    drop(ps_re);
    drop(spare);
    std::fs::remove_dir_all(&dir).ok();
}

/// Chaos drill 2: SIGKILL the migration DESTINATION (the `--join` spare)
/// mid-copy. The reshard must roll back — no ROUTING commit, no orphaned
/// node range — and the untouched 2-shard layout must finish the run at
/// ≤1e-6 parity without any restart at all.
#[test]
fn sigkill_destination_mid_copy_rolls_back_without_orphaned_nodes() {
    let steps = 15;
    let dir = tmp_dir("killdst");
    let baseline = baseline_trainer(steps).run_rust().unwrap();

    let slow = [("PERSIA_MIGRATE_DELAY_MS", "1500")];
    let (ps_a, addr_a) = spawn_ps("127.0.0.1:0", Some("0..4"), steps, &dir, &slow);
    let (ps_b, addr_b) = spawn_ps("127.0.0.1:0", Some("4..6"), steps, &dir, &slow);
    let (mut spare, addr_c) = spawn_ps("127.0.0.1:0", None, steps, &dir, &[]);

    let mut tr =
        Proc::spawn(&train_args(&format!("{addr_a},{addr_b},{addr_c}"), steps, &dir, &[]));

    // Once the source starts streaming, the spare has PREPAREd and is
    // receiving rows: kill it mid-copy.
    wait_either(&ps_a, &ps_b, "RESHARD: migrating node", Duration::from_secs(240));
    spare.kill();

    tr.wait_for_line("RESHARD aborted", Duration::from_secs(120))
        .unwrap_or_else(|| panic!("no clean rollback:\n{}", tr.output_snapshot()));
    let status = tr
        .wait_timeout(Duration::from_secs(300))
        .unwrap_or_else(|| panic!("run hung after the rollback:\n{}", tr.output_snapshot()));
    assert!(status.success(), "run failed after the rollback:\n{}", tr.output_snapshot());
    let out = tr.output_snapshot();

    // No commit, no orphan: the old table still owns every node, and the
    // deployment that ran on it matched the reference bit for bit.
    assert!(!out.contains("RESHARD epoch"), "a dead destination must not commit:\n{out}");
    assert!(
        load_routing(&dir).expect("readable dir").is_none(),
        "rolled-back reshard persisted a ROUTING table"
    );

    assert_run_matches_baseline(&out, &baseline, "destination-kill drill");

    drop(ps_a);
    drop(ps_b);
    std::fs::remove_dir_all(&dir).ok();
}

/// One full train run over a private 2-shard + spare fleet, with the
/// reshard probe armed. Returns the trainer's combined output. `extra`
/// rides on BOTH the shards and the trainer (flag parsing is last-wins, so
/// appending `--deterministic false` overrides the shared default).
fn run_fleet(tag: &str, steps: usize, extra: &[&str]) -> String {
    let dir = tmp_dir(tag);
    let (ps_a, addr_a) = spawn_ps_extra("127.0.0.1:0", Some("0..4"), steps, &dir, &[], extra);
    let (ps_b, addr_b) = spawn_ps_extra("127.0.0.1:0", Some("4..6"), steps, &dir, &[], extra);
    let (spare, addr_c) = spawn_ps_extra("127.0.0.1:0", None, steps, &dir, &[], extra);
    let mut tr =
        Proc::spawn(&train_args(&format!("{addr_a},{addr_b},{addr_c}"), steps, &dir, extra));
    let status = tr
        .wait_timeout(Duration::from_secs(300))
        .unwrap_or_else(|| panic!("{tag}: run hung:\n{}", tr.output_snapshot()));
    assert!(status.success(), "{tag}: run failed:\n{}", tr.output_snapshot());
    let out = tr.output_snapshot();
    drop(ps_a);
    drop(ps_b);
    drop(spare);
    std::fs::remove_dir_all(&dir).ok();
    out
}

/// ISSUE-10 drill: the worker-side bounded-staleness cache rides a live
/// 2→3-shard split. Two identical non-deterministic FullSync runs under
/// `--optimizer sgd` (the mirror push policy), one with the cache off as
/// the reference and one with it on: the cached run must flush the whole
/// cache at the routing-epoch bump, actually serve hits, and stay within
/// the 1e-6 acceptance bound of the uncached reference on every loss and
/// the final AUC.
#[test]
fn worker_cache_flushes_on_epoch_bump_and_matches_uncached_reference() {
    let steps = 30;
    // Non-deterministic on purpose: deterministic mode force-disables the
    // cache (bitwise parity), so the drill must run the real async path.
    let base = ["--optimizer", "sgd", "--deterministic", "false"];

    let mut off = base.to_vec();
    off.extend(["--ew-cache", "false"]);
    let out_off = run_fleet("cacheoff", steps, &off);
    assert!(
        out_off.contains("RESHARD epoch 1 committed"),
        "uncached reference never resharded:\n{out_off}"
    );
    assert!(
        !out_off.contains("EW-CACHE:"),
        "--ew-cache false must be a strict no-op:\n{out_off}"
    );

    let out_on = run_fleet("cacheon", steps, &base);
    assert!(
        out_on.contains("RESHARD epoch 1 committed"),
        "cached run never resharded:\n{out_on}"
    );
    // The commit bumped the routing epoch; the next fetch must have dropped
    // the whole cache (rows cached under the old layout are unsafe).
    let flush = out_on
        .lines()
        .find(|l| l.contains("EW-CACHE: flushed") && l.contains("routing epoch 0 -> 1"))
        .unwrap_or_else(|| panic!("no epoch-bump cache flush in:\n{out_on}"));
    assert!(flush.contains("rows"), "malformed flush line: {flush}");
    // The cache did real work: the end-of-run stats line reports hits.
    let stats = out_on
        .lines()
        .find(|l| l.starts_with("EW-CACHE: hits="))
        .unwrap_or_else(|| panic!("no end-of-run cache stats in:\n{out_on}"));
    let hits: u64 = stats
        .split_whitespace()
        .find_map(|f| f.strip_prefix("hits="))
        .unwrap()
        .parse()
        .unwrap();
    assert!(hits > 0, "cache never served a hit: {stats}");

    // Training parity: every printed loss and the final report within the
    // 1e-6 acceptance bound of the uncached reference. (Under SGD the
    // mirror keeps cached rows bitwise-coherent for this single-writer
    // deployment, so the bound is loose — but the contract is 1e-6.)
    let got = parse_losses(&out_on);
    let want = parse_losses(&out_off);
    assert_eq!(got.len(), want.len(), "loss curve lengths differ");
    for ((s_on, l_on), (s_off, l_off)) in got.iter().zip(&want) {
        assert_eq!(s_on, s_off, "loss curves sampled different steps");
        assert!(
            (l_on - l_off).abs() <= 1e-6,
            "step {s_on}: cached loss {l_on} vs uncached {l_off}"
        );
    }
    let (loss_on, auc_on) = parse_parity(&out_on);
    let (loss_off, auc_off) = parse_parity(&out_off);
    assert!(
        (loss_on - loss_off).abs() <= 1e-6,
        "final loss {loss_on} vs uncached {loss_off}"
    );
    assert!(
        (auc_on - auc_off).abs() <= 1e-6,
        "final AUC {auc_on} vs uncached {auc_off}"
    );
}
