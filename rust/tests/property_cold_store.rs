//! Property tests for the disk-backed cold tier and the tiered store.
//!
//! The cold file is the crash surface of tiered storage: a `serve-ps`
//! process that just died leaves behind whatever bytes made it to disk, and
//! the restart path re-opens that file as-is. Two families of properties
//! pin the §4.2.4-grade behavior:
//!
//! 1. **Corruption totality** — arbitrary, truncated, or bit-flipped cold
//!    files never panic `ColdStore::open`, and no amount of on-disk damage
//!    may ever surface a row whose CRC no longer matches: a read returns
//!    the exact bytes that were written, or reports the row absent.
//! 2. **Tiered equivalence** — an arbitrary interleaving of lookups and
//!    in-place writes against a [`TieredStore`] (demotions, promotions,
//!    admission-gate bypasses included) serves exactly the rows a plain
//!    `HashMap` reference model would, row for row, byte for byte.

use std::collections::HashMap;
use std::path::PathBuf;

use persia::embedding::store::EmbeddingStore;
use persia::embedding::{ColdStore, TieredStore};
use persia::util::quickcheck::forall;
use persia::util::Rng;

fn tmp_dir(tag: &str, salt: u64) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("persia_prop_cold_{tag}_{}_{salt}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Write a deterministic set of rows derived from `seed`; return the truth.
fn build_cold_file(path: &PathBuf, row_width: usize, seed: u64) -> HashMap<u64, Vec<f32>> {
    let mut cs = ColdStore::open(path, row_width).unwrap();
    let mut rng = Rng::new(seed);
    let mut truth = HashMap::new();
    for _ in 0..rng.range(1, 40) {
        let key = rng.below(64);
        let row: Vec<f32> = (0..row_width).map(|_| rng.below(1000) as f32 * 0.25).collect();
        cs.put(key, &row).unwrap();
        truth.insert(key, row);
    }
    // A few removes so the free list and zeroed slots are exercised too.
    for _ in 0..rng.range(0, 6) {
        let key = rng.below(64);
        cs.remove(key).unwrap();
        truth.remove(&key);
    }
    truth
}

/// Reopen `path` and check every truth row is either served exactly or
/// reported absent — never a wrong value, never a panic.
fn exact_or_absent(path: &PathBuf, row_width: usize, truth: &HashMap<u64, Vec<f32>>) -> bool {
    let Ok(mut cs) = ColdStore::open(path, row_width) else {
        // Header damage: refusing the whole file is a legal outcome.
        return true;
    };
    let mut row = vec![0.0f32; row_width];
    for (&key, want) in truth {
        match cs.get_into(key, &mut row) {
            Err(_) => return false, // I/O errors don't belong in this test
            Ok(false) => {}         // dropped by the CRC check: fine
            Ok(true) => {
                if &row != want {
                    return false; // corrupt bytes surfaced — the one sin
                }
            }
        }
    }
    true
}

#[test]
fn bit_flipped_cold_files_never_surface_bad_rows() {
    forall(
        81,
        120,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let dir = tmp_dir("flip", seed);
            let path = dir.join("shard.bin");
            let truth = build_cold_file(&path, 3, seed);
            let mut bytes = std::fs::read(&path).unwrap();
            let mut rng = Rng::new(seed ^ 0xD15EA5E);
            for _ in 0..rng.range(1, 6) {
                let at = rng.below(bytes.len() as u64) as usize;
                bytes[at] ^= 1 << rng.below(8);
            }
            std::fs::write(&path, &bytes).unwrap();
            let ok = exact_or_absent(&path, 3, &truth);
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    )
}

#[test]
fn truncated_cold_files_keep_the_surviving_prefix_exact() {
    forall(
        82,
        100,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let dir = tmp_dir("trunc", seed);
            let path = dir.join("shard.bin");
            let truth = build_cold_file(&path, 2, seed);
            let len = std::fs::metadata(&path).unwrap().len();
            let cut = Rng::new(seed ^ 0xCAFE).below(len + 1);
            let bytes = std::fs::read(&path).unwrap();
            std::fs::write(&path, &bytes[..cut as usize]).unwrap();
            // Rows past the cut are gone; rows before it must still be exact.
            let ok = exact_or_absent(&path, 2, &truth);
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    )
}

#[test]
fn arbitrary_bytes_as_a_cold_file_never_panic() {
    forall(
        83,
        150,
        |rng: &mut Rng| {
            let n = rng.below(400) as usize;
            let mut bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            // Splice in the valid magic + row width half the time so the
            // slot scan runs over the garbage body.
            if rng.below(2) == 0 && bytes.len() >= 16 {
                bytes[..8].copy_from_slice(b"PCLD0001");
                bytes[8..16].copy_from_slice(&2u64.to_le_bytes());
            }
            bytes
        },
        |bytes| {
            let salt = bytes.len() as u64 ^ bytes.first().copied().unwrap_or(0) as u64;
            let dir = tmp_dir("arb", salt);
            let path = dir.join("shard.bin");
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(&path, bytes).unwrap();
            // Open is total; if it succeeds, every indexed row re-verifies
            // its CRC on read, so a sweep can only yield absences or rows
            // that genuinely carry a matching checksum.
            let ok = match ColdStore::open(&path, 2) {
                Err(_) => true,
                Ok(mut cs) => {
                    let mut row = [0.0f32; 2];
                    cs.keys_sorted().iter().all(|&k| cs.get_into(k, &mut row).is_ok())
                }
            };
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    )
}

#[test]
fn corrupt_snapshot_blobs_are_rejected_not_panicked() {
    forall(
        84,
        150,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let dir = tmp_dir("snap", seed);
            let path = dir.join("shard.bin");
            build_cold_file(&path, 2, seed);
            let mut cs = ColdStore::open(&path, 2).unwrap();
            let good = cs.snapshot_bytes().unwrap();
            let mut rng = Rng::new(seed ^ 0xBEEF);
            let mutated = if rng.below(2) == 0 {
                let mut b = good.clone();
                if b.is_empty() {
                    b
                } else {
                    let at = rng.below(b.len() as u64) as usize;
                    b[at] ^= 1 << rng.below(8);
                    b
                }
            } else {
                good[..rng.below(good.len() as u64) as usize].to_vec()
            };
            let ok = if mutated == good {
                cs.restore_bytes(&mutated).is_ok()
            } else {
                // Any real mutation must be caught by the shape/order checks
                // or land as a structurally valid (decodable) snapshot —
                // either way restore_bytes is total.
                match cs.restore_bytes(&mutated) {
                    Err(_) => true,
                    Ok(()) => ColdStore::decode_snapshot(&mutated).is_ok(),
                }
            };
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    )
}

/// Random interleavings of lookups and writes against the tiered store
/// match a HashMap reference model exactly — across demotions, promotions,
/// and admission-gate bypasses.
#[test]
fn tiered_interleaving_matches_reference_model() {
    forall(
        85,
        60,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let hot_cap = rng.range(1, 6) as usize;
            let width = rng.range(1, 4) as usize;
            let threshold = 1 + rng.below(3) as u8;
            let dir = tmp_dir("tiered", seed);
            let cold = ColdStore::open(&dir.join("cold.bin"), width).unwrap();
            let mut ts = TieredStore::new(hot_cap, cold, threshold).unwrap();
            let mut model: HashMap<u64, Vec<f32>> = HashMap::new();

            let mut ok = true;
            for _ in 0..rng.range(1, 250) {
                let key = rng.below(24);
                let init_val = key as f32 + 0.5;
                let row = ts
                    .get_or_insert_with(key, &mut |r| r.fill(init_val))
                    .unwrap();
                let want = model
                    .entry(key)
                    .or_insert_with(|| vec![init_val; width]);
                if row != want.as_slice() {
                    ok = false;
                    break;
                }
                // Half the time, mutate the served row in place (the PS's
                // put_grad path) and mirror it in the model.
                if rng.below(2) == 0 {
                    let at = rng.below(width as u64) as usize;
                    let v = rng.below(100) as f32 * 0.125;
                    row[at] = v;
                    want[at] = v;
                }
            }
            ok = ok
                && ts.len() == model.len()
                && ts.check_invariants().is_ok()
                && ts.hot_len() <= hot_cap;
            // Every key the model knows is still served exactly, with no
            // re-materialization allowed.
            if ok {
                for (&key, want) in &model {
                    let row = ts
                        .get_or_insert_with(key, &mut |_| panic!("resident key re-initialized"))
                        .unwrap();
                    if row != want.as_slice() {
                        ok = false;
                        break;
                    }
                }
            }
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    )
}

/// Snapshot/restore of a tiered store mid-interleaving preserves every row
/// exactly (both tiers), and the restored store keeps serving the model.
#[test]
fn tiered_snapshot_restore_preserves_every_row() {
    forall(
        86,
        40,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let width = 2;
            let dir = tmp_dir("tsnap", seed);
            let cold = ColdStore::open(&dir.join("cold.bin"), width).unwrap();
            let mut ts = TieredStore::new(2, cold, 1).unwrap();
            let mut model: HashMap<u64, Vec<f32>> = HashMap::new();
            for _ in 0..rng.range(1, 80) {
                let key = rng.below(16);
                let row = ts.get_or_insert_with(key, &mut |r| r.fill(key as f32)).unwrap();
                let want = model.entry(key).or_insert_with(|| vec![key as f32; width]);
                row[1] += 1.0;
                want[1] += 1.0;
            }
            let hot = ts.snapshot_hot().unwrap();
            let cold_snap = ts.snapshot_cold().unwrap().expect("tiered store has a cold tier");
            ts.wipe().unwrap();
            ts.restore_cold(&cold_snap).unwrap();
            ts.restore_hot(&hot).unwrap();
            let mut ok = ts.len() == model.len() && ts.check_invariants().is_ok();
            if ok {
                for (&key, want) in &model {
                    let row = ts
                        .get_or_insert_with(key, &mut |_| panic!("row lost across restore"))
                        .unwrap();
                    if row != want.as_slice() {
                        ok = false;
                        break;
                    }
                }
            }
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    )
}
