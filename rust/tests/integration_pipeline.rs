//! Loopback soak of the event-driven service core: many pipelined clients
//! hammering one `serve_rpc` readiness-loop server (the exact stack
//! `serve-ps` and `serve-embedding-worker` run), with out-of-order
//! completion claims, chaos connections throwing garbage mid-stream, and a
//! clean sleep-free shutdown at the end.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use persia::comm::rpc::{PipelinedClient, RpcServer};
use persia::comm::wire::{WireReader, WireWriter};
use persia::service::serve_rpc;

/// Echo-with-work message kind: request carries `[tag]` + payload floats,
/// response carries the same tag and the payload doubled.
const KIND_ECHO: u32 = 0x7001;

fn echo_server() -> Arc<RpcServer> {
    let mut server = RpcServer::new();
    server.register(
        KIND_ECHO,
        Box::new(move |msg| {
            let r = WireReader::parse(msg)?;
            let tag = r.u64(0)?;
            let payload = r.f32(1)?;
            let doubled: Vec<f32> = payload.iter().map(|x| x * 2.0).collect();
            let mut w = WireWriter::new(KIND_ECHO);
            w.put_u64(&tag).put_f32(&doubled);
            Ok(w.finish())
        }),
    );
    Arc::new(server)
}

fn echo_request(tag: u64) -> Vec<u8> {
    let payload: Vec<f32> = (0..16).map(|i| (tag as f32) + (i as f32) * 0.25).collect();
    let mut w = WireWriter::new(KIND_ECHO);
    w.put_u64(&[tag]).put_f32(&payload);
    w.finish()
}

fn check_echo(tag: u64, resp: &[u8]) {
    let r = WireReader::parse(resp).unwrap();
    assert_eq!(r.kind(), KIND_ECHO);
    assert_eq!(r.u64(0).unwrap(), vec![tag], "response for the wrong request");
    let doubled = r.f32(1).unwrap();
    assert_eq!(doubled.len(), 16);
    for (i, d) in doubled.iter().enumerate() {
        let want = ((tag as f32) + (i as f32) * 0.25) * 2.0;
        assert_eq!(*d, want, "tag {tag} element {i}");
    }
}

/// Start `serve_rpc` on its own thread; returns (addr, stop, join handle).
fn start_server() -> (String, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let rpc = echo_server();
    let stop2 = stop.clone();
    let h = std::thread::spawn(move || serve_rpc(listener, rpc, stop2, "soak-test"));
    (addr, stop, h)
}

fn shutdown(addr: &str, stop: &AtomicBool, h: std::thread::JoinHandle<()>) {
    stop.store(true, Ordering::SeqCst);
    // The no-op connect wakes the accept loop so shutdown needs no sleeps.
    let _ = TcpStream::connect(addr);
    h.join().unwrap();
}

/// Many concurrent pipelined clients, each keeping a full in-flight window
/// and claiming completions out of order, all against one readiness-loop
/// server — every reply must match its request, and the server must shut
/// down cleanly afterwards.
#[test]
fn pipelined_clients_soak_the_event_loop_server() {
    const CLIENTS: usize = 8;
    const REQUESTS: u64 = 150;
    const WINDOW: usize = 16;
    let (addr, stop, server) = start_server();

    let workers: Vec<_> = (0..CLIENTS as u64)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let client =
                    PipelinedClient::connect(&addr, WINDOW, Some(Duration::from_secs(30)))
                        .unwrap();
                let mut sent = 0u64;
                while sent < REQUESTS {
                    let batch = WINDOW.min((REQUESTS - sent) as usize) as u64;
                    let mut pending = Vec::new();
                    for i in 0..batch {
                        let tag = c * 1_000_000 + sent + i;
                        if i % 5 == 4 {
                            // Interleave the synchronous path with the
                            // window partially occupied by async requests.
                            check_echo(tag, &client.call(&echo_request(tag)).unwrap());
                        } else {
                            pending.push((tag, client.call_async(&echo_request(tag)).unwrap()));
                        }
                    }
                    // Claim completions in reverse issue order: the demux
                    // map, not arrival order, must route each reply.
                    while let Some((tag, reply)) = pending.pop() {
                        check_echo(tag, &reply.wait().unwrap());
                    }
                    sent += batch;
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    shutdown(&addr, &stop, server);
}

/// Chaos connections — mid-stream disconnects, garbage bytes, oversized
/// length prefixes — must cost only their own connection: a well-behaved
/// pipelined client sharing the server keeps getting correct replies.
#[test]
fn garbage_connections_do_not_disturb_pipelined_clients() {
    let (addr, stop, server) = start_server();
    let client =
        PipelinedClient::connect(&addr, 8, Some(Duration::from_secs(30))).unwrap();

    for round in 0..40u64 {
        match round % 4 {
            0 => {
                // Abrupt disconnect with a partial length prefix in flight.
                let mut s = TcpStream::connect(&addr).unwrap();
                s.write_all(&[7u8, 0]).unwrap();
            }
            1 => {
                // An oversized frame announcement.
                let mut s = TcpStream::connect(&addr).unwrap();
                s.write_all(&u32::MAX.to_le_bytes()).unwrap();
            }
            2 => {
                // A plausible length followed by garbage (bad corr + kind).
                let mut s = TcpStream::connect(&addr).unwrap();
                let junk = [0xABu8; 32];
                s.write_all(&(junk.len() as u32).to_le_bytes()).unwrap();
                s.write_all(&junk).unwrap();
            }
            _ => {
                // Connect-and-vanish.
                drop(TcpStream::connect(&addr).unwrap());
            }
        }
        // The good client is unaffected, pipelined or not.
        let a = client.call_async(&echo_request(round)).unwrap();
        let b = client.call_async(&echo_request(round + 10_000)).unwrap();
        check_echo(round + 10_000, &b.wait().unwrap());
        check_echo(round, &a.wait().unwrap());
    }
    drop(client);
    shutdown(&addr, &stop, server);
}
