//! Multi-process NN workers: the ISSUE-3 acceptance drill.
//!
//! * In-process cross-check: two `Trainer::run_rank` threads joined by a
//!   real loopback TCP ring match the threaded `Trainer::run` bit-for-bit
//!   (asserted ≤ 1e-6, observed exact) in deterministic FullSync.
//! * Real processes: two `persia train-worker` children (rank 0 hosting the
//!   rendezvous on an ephemeral port) against two `persia serve-ps` shard
//!   children reproduce the single-process threaded run's loss curve and
//!   AUC within 1e-6.
//! * SIGKILL one rank mid-ring: the survivors error out cleanly within the
//!   ring timeout (no hang) and every child is reaped.
//! * A worker started with different flags is rejected at the rendezvous
//!   (config-fingerprint policy), and both sides exit nonzero.

use std::io::BufRead;
use std::process::{Child, Command, ExitStatus, Stdio};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use persia::allreduce::RingRendezvous;
use persia::config::{
    BenchPreset, ClusterConfig, NetModelConfig, RingConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::embedding::EmbeddingPs;
use persia::hybrid::{DenseComm, Trainer};

const PRESET: &str = "taobao";
const DENSE: &str = "tiny";
const CAPACITY: usize = 2048;
const SEED: u64 = 42;
const BATCH: usize = 32;

/// A trainer built through the same preset pipeline the CLI uses, so its
/// config fingerprint provably matches `train-worker` children started with
/// the matching flags.
fn preset_trainer(steps: usize, world: usize) -> Trainer {
    let preset = BenchPreset::by_name(PRESET).unwrap();
    let model = preset.model(DENSE);
    let emb_cfg = preset.embedding(&model, CAPACITY);
    let rows = preset.embedding(&model, 1).rows_per_group;
    let cluster = ClusterConfig {
        n_nn_workers: world,
        n_emb_workers: 2,
        net: NetModelConfig::disabled(),
    };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: BATCH,
        lr: 0.05,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: SEED,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    t
}

fn ring_cfg(rank: usize, world: usize, rendezvous: &str) -> RingConfig {
    RingConfig {
        rendezvous: rendezvous.to_string(),
        rank,
        world,
        bind_host: "127.0.0.1".to_string(),
        timeout_ms: 30_000,
        compress: false,
    }
}

// ---------------------------------------------------------------------------
// In-process cross-check: run_rank × TCP ring vs run × thread ring.
// ---------------------------------------------------------------------------

/// Two `run_rank` calls in one test process, joined by a genuine loopback
/// TCP ring and sharing one in-process PS — the exact structure of a
/// 2-process deployment, minus the process boundary — must reproduce the
/// all-threads `run` numbers.
#[test]
fn tcp_ring_run_rank_matches_threaded_run() {
    let steps = 30;
    let baseline = preset_trainer(steps, 2).run_rust().unwrap();

    let template = preset_trainer(steps, 2);
    let shared_ps = Arc::new(EmbeddingPs::new(
        &template.emb_cfg,
        template.model.emb_dim_per_group,
        template.train.seed,
    ));
    let rz0 = RingRendezvous::bind(&ring_cfg(0, 2, "127.0.0.1:0")).unwrap();
    let rendezvous = rz0.rendezvous_addr().unwrap().to_string();

    let spawn_rank = |rank: usize, rz: Option<RingRendezvous>, rendezvous: String| {
        let shared_ps = shared_ps.clone();
        std::thread::spawn(move || {
            let mut t = preset_trainer(steps, 2);
            t.ps_backend = Some(shared_ps);
            let fp = t.config_fingerprint();
            let factory = t.rust_engine_factory();
            t.run_rank(&factory, move |net| {
                let rz = match rz {
                    Some(rz) => rz,
                    None => RingRendezvous::bind(&ring_cfg(rank, 2, &rendezvous))?,
                };
                Ok(Box::new(rz.connect(fp, net)?) as Box<dyn DenseComm>)
            })
            .unwrap()
        })
    };
    let h0 = spawn_rank(0, Some(rz0), String::new());
    let h1 = spawn_rank(1, None, rendezvous);
    let out0 = h0.join().unwrap();
    let out1 = h1.join().unwrap();

    // Rank 0 carries the curves; both ranks end with identical dense params
    // (the ring is synchronous).
    assert_eq!(baseline.tracker.losses.len(), out0.tracker.losses.len());
    for ((sa, la), (sb, lb)) in baseline.tracker.losses.iter().zip(&out0.tracker.losses) {
        assert_eq!(sa, sb);
        assert!((la - lb).abs() <= 1e-6, "step {sa}: loss {la} (threads) vs {lb} (tcp)");
    }
    let auc_a = baseline.report.final_auc.unwrap();
    let auc_b = out0.report.final_auc.unwrap();
    assert!((auc_a - auc_b).abs() <= 1e-6, "AUC {auc_a} (threads) vs {auc_b} (tcp)");
    assert_eq!(baseline.final_params.len(), out0.final_params.len());
    for (a, b) in baseline.final_params.iter().zip(&out0.final_params) {
        assert!((a - b).abs() <= 1e-6, "final params diverged: {a} vs {b}");
    }
    for (a, b) in out0.final_params.iter().zip(&out1.final_params) {
        assert_eq!(a, b, "ranks disagree on synchronized dense params");
    }
    // The run meaningfully trained.
    let early: f32 =
        baseline.tracker.losses[..5].iter().map(|(_, l)| l).sum::<f32>() / 5.0;
    assert!(baseline.tracker.recent_loss(5).unwrap() < early, "did not learn");
}

// ---------------------------------------------------------------------------
// Real child processes.
// ---------------------------------------------------------------------------

/// A spawned `persia` child with its stdout+stderr streamed into a line
/// buffer (so pipes never fill) and kill-on-drop reaping.
struct Proc {
    child: Child,
    lines: Arc<Mutex<Vec<String>>>,
    readers: Vec<JoinHandle<()>>,
}

impl Proc {
    fn spawn(args: &[String]) -> Proc {
        let exe = env!("CARGO_BIN_EXE_persia");
        let mut child = Command::new(exe)
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn persia child");
        let lines = Arc::new(Mutex::new(Vec::new()));
        let mut readers = Vec::new();
        let stdout = child.stdout.take().expect("stdout piped");
        let stderr = child.stderr.take().expect("stderr piped");
        for reader in [Box::new(stdout) as Box<dyn std::io::Read + Send>, Box::new(stderr)] {
            let lines = lines.clone();
            readers.push(std::thread::spawn(move || {
                let buf = std::io::BufReader::new(reader);
                for line in buf.lines() {
                    match line {
                        Ok(l) => lines.lock().unwrap().push(l),
                        Err(_) => break,
                    }
                }
            }));
        }
        Proc { child, lines, readers }
    }

    /// First buffered line containing `pat`, waiting up to `timeout`.
    fn wait_for_line(&mut self, pat: &str, timeout: Duration) -> Option<String> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(l) =
                self.lines.lock().unwrap().iter().find(|l| l.contains(pat)).cloned()
            {
                return Some(l);
            }
            if Instant::now() >= deadline {
                return None;
            }
            if let Ok(Some(_)) = self.child.try_wait() {
                // Child exited; drain whatever the readers still push.
                std::thread::sleep(Duration::from_millis(100));
                return self
                    .lines
                    .lock()
                    .unwrap()
                    .iter()
                    .find(|l| l.contains(pat))
                    .cloned();
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Wait for exit up to `timeout`.
    fn wait_timeout(&mut self, timeout: Duration) -> Option<ExitStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.child.try_wait().expect("try_wait") {
                Some(status) => return Some(status),
                None if Instant::now() >= deadline => return None,
                None => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    fn output_snapshot(&self) -> String {
        self.lines.lock().unwrap().join("\n")
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        self.kill();
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
    }
}

/// Spawn one `persia serve-ps` shard and wait for its listening line.
fn spawn_ps(node_range: Option<&str>) -> Proc {
    let mut args: Vec<String> = [
        "serve-ps",
        "--preset",
        PRESET,
        "--dense",
        DENSE,
        "--addr",
        "127.0.0.1:0",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    args.extend(["--shard-capacity".to_string(), CAPACITY.to_string()]);
    args.extend(["--seed".to_string(), SEED.to_string()]);
    if let Some(r) = node_range {
        args.extend(["--node-range".to_string(), r.to_string()]);
    }
    let mut p = Proc::spawn(&args);
    let line = p
        .wait_for_line("listening on ", Duration::from_secs(30))
        .unwrap_or_else(|| panic!("serve-ps never listened:\n{}", p.output_snapshot()));
    let addr = line
        .split("listening on ")
        .nth(1)
        .and_then(|r| r.split_whitespace().next())
        .expect("address in listening line")
        .to_string();
    p.lines.lock().unwrap().push(format!("ADDR {addr}"));
    p
}

fn ps_addr(p: &Proc) -> String {
    p.lines
        .lock()
        .unwrap()
        .iter()
        .find_map(|l| l.strip_prefix("ADDR ").map(|s| s.to_string()))
        .expect("ps addr recorded")
}

/// Common `train-worker` argv. `steps` is separate so the fingerprint
/// mismatch test can vary it per rank.
fn worker_args(
    rank: usize,
    world: usize,
    rendezvous: &str,
    steps: usize,
    remote_ps: &str,
    ring_timeout_ms: u64,
) -> Vec<String> {
    [
        "train-worker",
        "--rank",
        &rank.to_string(),
        "--world",
        &world.to_string(),
        "--rendezvous",
        rendezvous,
        "--ring-timeout-ms",
        &ring_timeout_ms.to_string(),
        "--preset",
        PRESET,
        "--dense",
        DENSE,
        "--engine",
        "rust",
        "--mode",
        "sync",
        "--deterministic",
        "true",
        "--shard-capacity",
        &CAPACITY.to_string(),
        "--seed",
        &SEED.to_string(),
        "--batch",
        &BATCH.to_string(),
        "--lr",
        "0.05",
        "--tau",
        "4",
        "--steps",
        &steps.to_string(),
        "--eval-every",
        &steps.to_string(),
        "--emb-workers",
        "2",
        "--netsim",
        "false",
        "--compress",
        "false",
        "--remote-ps",
        remote_ps,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

/// Spawn rank 0 with an ephemeral rendezvous port and read the concrete
/// address it prints for the other ranks.
fn spawn_rank0(args_for: impl Fn(&str) -> Vec<String>) -> (Proc, String) {
    let mut p = Proc::spawn(&args_for("127.0.0.1:0"));
    let line = p
        .wait_for_line("rendezvous listening on ", Duration::from_secs(30))
        .unwrap_or_else(|| panic!("rank 0 never printed rendezvous:\n{}", p.output_snapshot()));
    let addr = line
        .split("rendezvous listening on ")
        .nth(1)
        .and_then(|r| r.split_whitespace().next())
        .expect("rendezvous address")
        .to_string();
    (p, addr)
}

fn parse_losses(output: &str) -> Vec<(u64, f32)> {
    let line = output
        .lines()
        .find(|l| l.starts_with("LOSSES "))
        .unwrap_or_else(|| panic!("no LOSSES line in:\n{output}"));
    line["LOSSES ".len()..]
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|pair| {
            let (s, l) = pair.split_once(':').expect("step:loss pair");
            (s.parse().unwrap(), l.parse().unwrap())
        })
        .collect()
}

fn parse_parity(output: &str) -> (f32, f64) {
    let line = output
        .lines()
        .find(|l| l.starts_with("PARITY "))
        .unwrap_or_else(|| panic!("no PARITY line in:\n{output}"));
    let mut loss = f32::NAN;
    let mut auc = f64::NAN;
    for field in line["PARITY ".len()..].split_whitespace() {
        if let Some(v) = field.strip_prefix("final_loss=") {
            loss = v.parse().unwrap();
        }
        if let Some(v) = field.strip_prefix("final_auc=") {
            auc = v.parse().unwrap_or(f64::NAN);
        }
    }
    (loss, auc)
}

/// The acceptance criterion: a 2-process `train-worker` deployment over
/// loopback TCP (against 2 PS shard processes) reproduces the
/// single-process threaded run's losses and AUC within 1e-6.
#[test]
fn two_process_train_workers_match_threaded_run() {
    let steps = 40;
    let baseline = preset_trainer(steps, 2).run_rust().unwrap();
    let base_auc = baseline.report.final_auc.unwrap();

    let ps0 = spawn_ps(Some("0..2"));
    let ps1 = spawn_ps(Some("2..4"));
    let remote = format!("{},{}", ps_addr(&ps0), ps_addr(&ps1));

    let (mut w0, rendezvous) =
        spawn_rank0(|rdzv| worker_args(0, 2, rdzv, steps, &remote, 60_000));
    let mut w1 = Proc::spawn(&worker_args(1, 2, &rendezvous, steps, &remote, 60_000));

    let s0 = w0
        .wait_timeout(Duration::from_secs(300))
        .unwrap_or_else(|| panic!("rank 0 hung:\n{}", w0.output_snapshot()));
    let s1 = w1
        .wait_timeout(Duration::from_secs(300))
        .unwrap_or_else(|| panic!("rank 1 hung:\n{}", w1.output_snapshot()));
    // Let the reader threads drain the last lines.
    std::thread::sleep(Duration::from_millis(200));
    assert!(s0.success(), "rank 0 failed:\n{}", w0.output_snapshot());
    assert!(s1.success(), "rank 1 failed:\n{}", w1.output_snapshot());

    let out0 = w0.output_snapshot();
    let losses = parse_losses(&out0);
    assert_eq!(losses.len(), baseline.tracker.losses.len());
    for ((sa, la), (sb, lb)) in baseline.tracker.losses.iter().zip(&losses) {
        assert_eq!(sa, sb);
        assert!(
            (la - lb).abs() <= 1e-6,
            "step {sa}: loss {la} (threads) vs {lb} (2 processes)"
        );
    }
    let (final_loss, final_auc) = parse_parity(&out0);
    assert!(
        (baseline.report.final_loss - final_loss).abs() <= 1e-6,
        "final loss {} (threads) vs {final_loss} (2 processes)",
        baseline.report.final_loss
    );
    assert!(
        (base_auc - final_auc).abs() <= 1e-6,
        "AUC {base_auc} (threads) vs {final_auc} (2 processes)"
    );
}

/// SIGKILL one rank mid-ring: the survivors must exit nonzero within the
/// ring timeout — no hang — and the test reaps every child.
#[test]
fn sigkill_one_rank_survivors_error_out_cleanly() {
    let ps = spawn_ps(None);
    let remote = ps_addr(&ps);
    // Steps chosen far beyond what can finish before the kill.
    let steps = 1_000_000;
    let (mut w0, rendezvous) =
        spawn_rank0(|rdzv| worker_args(0, 3, rdzv, steps, &remote, 8_000));
    let mut w1 = Proc::spawn(&worker_args(1, 3, &rendezvous, steps, &remote, 8_000));
    let mut w2 = Proc::spawn(&worker_args(2, 3, &rendezvous, steps, &remote, 8_000));

    // Wait until the ring is actually established and training has begun.
    w0.wait_for_line("ring connected: rank 0/3", Duration::from_secs(60))
        .unwrap_or_else(|| panic!("ring never formed:\n{}", w0.output_snapshot()));
    std::thread::sleep(Duration::from_millis(500));

    // SIGKILL rank 1 mid-ring.
    w1.kill();

    // Survivors notice (socket error or ring timeout) and exit nonzero
    // well within the timeout budget.
    let s0 = w0
        .wait_timeout(Duration::from_secs(30))
        .unwrap_or_else(|| panic!("rank 0 hung after peer SIGKILL:\n{}", w0.output_snapshot()));
    let s2 = w2
        .wait_timeout(Duration::from_secs(30))
        .unwrap_or_else(|| panic!("rank 2 hung after peer SIGKILL:\n{}", w2.output_snapshot()));
    std::thread::sleep(Duration::from_millis(200));
    assert!(!s0.success(), "rank 0 must fail when a ring peer dies");
    assert!(!s2.success(), "rank 2 must fail when a ring peer dies");
    let combined = format!("{}\n{}", w0.output_snapshot(), w2.output_snapshot());
    assert!(
        combined.contains("ring"),
        "survivor errors should mention the ring:\n{combined}"
    );
    // Drop reaps w0/w2 handles and the PS child; w1 was already reaped.
}

/// A worker whose flags differ (here: a different --steps) is rejected at
/// the rendezvous by the config-fingerprint handshake; both sides fail.
#[test]
fn mismatched_worker_rejected_at_rendezvous() {
    let ps = spawn_ps(None);
    let remote = ps_addr(&ps);
    let (mut w0, rendezvous) =
        spawn_rank0(|rdzv| worker_args(0, 2, rdzv, 40, &remote, 60_000));
    // Same PS flags (so the PS handshake passes), different train length.
    let mut w1 = Proc::spawn(&worker_args(1, 2, &rendezvous, 41, &remote, 60_000));

    let s0 = w0
        .wait_timeout(Duration::from_secs(120))
        .unwrap_or_else(|| panic!("rank 0 hung on mismatch:\n{}", w0.output_snapshot()));
    let s1 = w1
        .wait_timeout(Duration::from_secs(120))
        .unwrap_or_else(|| panic!("rank 1 hung on mismatch:\n{}", w1.output_snapshot()));
    std::thread::sleep(Duration::from_millis(200));
    assert!(!s0.success(), "rank 0 must reject the mismatched worker");
    assert!(!s1.success(), "the mismatched worker must fail");
    assert!(
        w0.output_snapshot().contains("fingerprint"),
        "rank 0 error should cite the fingerprint:\n{}",
        w0.output_snapshot()
    );
    assert!(
        w1.output_snapshot().contains("rejected"),
        "rank 1 should report the rejection:\n{}",
        w1.output_snapshot()
    );
}
