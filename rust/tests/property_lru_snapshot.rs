//! Property tests: restoring an [`LruStore`] snapshot from arbitrary,
//! truncated, or bit-flipped bytes NEVER panics — it either returns a
//! usable store (invariants intact) or a clean `Err`.
//!
//! Snapshot restore is the §4.2.4 *failure recovery* path: a PS node that
//! just crashed is being rebuilt from whatever bytes survived, possibly a
//! torn write. The original implementation indexed `head`/`tail`/
//! `prev`/`next` straight into the slot array and would panic (or hang on a
//! link cycle) on corrupt input — taking down the recovering process a
//! second time. These properties pin the hardened behavior. (A panic or
//! hang here fails the test run; no `catch_unwind` games needed.)

use persia::embedding::LruStore;
use persia::util::quickcheck::forall;
use persia::util::Rng;

/// Build a deterministic, well-used store: some inserts, touches, removes.
fn build_store(rng: &mut Rng) -> LruStore {
    let cap = rng.range(1, 12) as usize;
    let width = rng.range(1, 6) as usize;
    let mut lru = LruStore::new(cap, width);
    for _ in 0..rng.range(0, 200) {
        let k = rng.below(40);
        match rng.below(4) {
            0 => {
                lru.get(k);
            }
            1 => {
                lru.remove(k);
            }
            _ => {
                let v = k as f32;
                lru.get_or_insert_with(k, |row| row.fill(v));
            }
        }
    }
    lru
}

/// If `from_bytes` accepts the input, the result must be fully usable.
fn usable_or_err(bytes: &[u8]) -> bool {
    match LruStore::from_bytes(bytes) {
        Err(_) => true,
        Ok(mut store) => {
            if store.check_invariants().is_err() {
                return false;
            }
            // Exercise the restored store: read every surviving key, then
            // insert through it (possibly evicting) and re-check.
            for k in store.keys_mru_order() {
                if store.get(k).is_none() {
                    return false;
                }
            }
            store.get_or_insert_with(9_999_999, |row| row.fill(1.0));
            store.check_invariants().is_ok()
        }
    }
}

#[test]
fn arbitrary_bytes_never_panic() {
    // Fully random buffers, with the valid magic spliced in half the time so
    // the walk past the header check is exercised too.
    forall(
        71,
        400,
        |rng: &mut Rng| {
            let n = rng.below(300) as usize;
            let mut bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            if rng.below(2) == 0 && bytes.len() >= 8 {
                bytes[..8].copy_from_slice(b"PLRU0001");
            }
            bytes
        },
        |bytes| usable_or_err(bytes),
    )
}

#[test]
fn bit_flipped_snapshots_never_panic() {
    // Take a *real* snapshot and flip a handful of random bytes anywhere
    // (header, slot links, values): restore must stay panic-free, and if it
    // accepts the bytes the store must still hold its invariants.
    forall(
        72,
        300,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let lru = build_store(&mut rng);
            let mut bytes = lru.to_bytes();
            for _ in 0..rng.range(1, 9) {
                let at = rng.below(bytes.len() as u64) as usize;
                bytes[at] ^= rng.below(256) as u8;
            }
            usable_or_err(&bytes)
        },
    )
}

#[test]
fn header_region_corruption_never_panics() {
    // Target the 40-byte header specifically (magic, capacity, row_width,
    // head, tail): these are the fields `from_bytes` derives every
    // allocation size and slot index from, so an unchecked read here was
    // the original panic vector. Bit-flips and whole-field rewrites with
    // adversarial values must both come back as a clean `Err` (or, for a
    // no-op rewrite, the original store).
    forall(
        75,
        300,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let lru = build_store(&mut rng);
            let mut bytes = lru.to_bytes();
            if rng.below(2) == 0 {
                // Single bit flip somewhere in the header.
                let at = rng.below(40) as usize;
                bytes[at] ^= 1 << rng.below(8);
            } else {
                // Rewrite one whole u64 header field with a hostile value:
                // 0, capacity, huge, NIL-adjacent, or overflow-inducing.
                let field = 8 + 8 * rng.below(4) as usize; // 8, 16, 24, 32
                let v = match rng.below(5) {
                    0 => 0u64,
                    1 => lru.capacity() as u64,
                    2 => u64::MAX,
                    3 => (u32::MAX as u64) - 1,
                    _ => u64::MAX / 8,
                };
                bytes[field..field + 8].copy_from_slice(&v.to_le_bytes());
            }
            usable_or_err(&bytes)
        },
    )
}

#[test]
fn truncated_snapshots_error_cleanly() {
    // Every strict prefix of a valid snapshot is rejected (the total length
    // can only match the header's own accounting).
    forall(
        73,
        120,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let lru = build_store(&mut rng);
            let bytes = lru.to_bytes();
            let cut = rng.below(bytes.len() as u64) as usize;
            LruStore::from_bytes(&bytes[..cut]).is_err()
        },
    )
}

#[test]
fn valid_snapshots_still_roundtrip() {
    // The hardening must not reject good snapshots: roundtrip preserves
    // content, order, and capacity exactly.
    forall(
        74,
        150,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let mut lru = build_store(&mut rng);
            let bytes = lru.to_bytes();
            let mut back = match LruStore::from_bytes(&bytes) {
                Ok(b) => b,
                Err(_) => return false,
            };
            if back.capacity() != lru.capacity()
                || back.row_width() != lru.row_width()
                || back.keys_mru_order() != lru.keys_mru_order()
            {
                return false;
            }
            for k in lru.keys_mru_order() {
                if back.get(k).map(|r| r.to_vec()) != lru.get(k).map(|r| r.to_vec()) {
                    return false;
                }
            }
            true
        },
    )
}
