//! The embedding-worker tier acceptance drill (ISSUE 4).
//!
//! * In-process parity: for every one of the 4 sync modes, a trainer going
//!   through a real loopback `EmbeddingWorkerServer` (which itself
//!   scatter-gathers a 2-shard `ShardedRemotePs`) reproduces the inline
//!   run's loss curve and AUC within 1e-6 (deterministic mode, observed
//!   exact — the raw-f32 wire is a memcpy).
//! * Real processes: `persia serve-embedding-worker` children (via
//!   `CARGO_BIN_EXE`) between 2 `serve-ps` shard children and a
//!   `persia train --embedding-workers` trainer match the inline run.
//! * SIGKILL one embedding-worker process mid-run with `--ew-failover
//!   true`: the survivor adopts the dead worker's rank (ADOPT_RANK +
//!   deterministic stream fast-forward), both NN ranks run to completion,
//!   and the loss curve stays within 1e-6 of the unkilled inline run.
//! * An embedding worker started with different flags is rejected at the
//!   INFO handshake (config-fingerprint policy).

use std::io::BufRead;
use std::process::{Child, Command, ExitStatus, Stdio};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use persia::comm::NetSim;
use persia::config::{
    BenchPreset, ClusterConfig, EmbWorkerConfig, NetModelConfig, ServiceConfig, TrainConfig,
    TrainMode,
};
use persia::data::SyntheticDataset;
use persia::embedding::EmbeddingPs;
use persia::hybrid::Trainer;
use persia::service::{
    EmbeddingWorkerServer, EwExpect, PsServer, RemoteEmbTier, ShardedRemotePs,
};

const PRESET: &str = "taobao";
const DENSE: &str = "tiny";
const CAPACITY: usize = 2048;
const SEED: u64 = 42;
const BATCH: usize = 32;

/// A trainer built through the same preset pipeline the CLI uses, so its
/// config fingerprint provably matches `serve-embedding-worker` children
/// started with the matching flags.
fn preset_trainer(mode: TrainMode, steps: usize, k: usize, m: usize) -> Trainer {
    let preset = BenchPreset::by_name(PRESET).unwrap();
    let model = preset.model(DENSE);
    let emb_cfg = preset.embedding(&model, CAPACITY);
    let rows = preset.embedding(&model, 1).rows_per_group;
    let cluster =
        ClusterConfig { n_nn_workers: k, n_emb_workers: m, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode,
        batch_size: BATCH,
        lr: 0.05,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: SEED,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    t
}

fn expect_of(t: &Trainer) -> EwExpect {
    EwExpect {
        fingerprint: t.config_fingerprint(),
        emb_dim: t.model.emb_dim(),
        nid_dim: t.model.nid_dim,
        batch_size: t.train.batch_size,
    }
}

// ---------------------------------------------------------------------------
// In-process parity: all 4 modes, against 2 PS shards.
// ---------------------------------------------------------------------------

/// The acceptance criterion: for every sync mode, training through a real
/// loopback embedding-worker service (fronting a 2-shard PS) reproduces the
/// inline run's losses and AUC within 1e-6.
#[test]
fn remote_tier_matches_inline_in_all_modes_against_two_ps_shards() {
    for mode in TrainMode::ALL {
        let steps = 24;
        let baseline = preset_trainer(mode, steps, 1, 1).run_rust().unwrap();

        // Two in-process PS shard servers over the preset's 4 PS nodes.
        let template = preset_trainer(mode, steps, 1, 1);
        let dim = template.model.emb_dim_per_group;
        let ps_a =
            Arc::new(EmbeddingPs::new_range(&template.emb_cfg, dim, SEED, 0..2));
        let ps_b =
            Arc::new(EmbeddingPs::new_range(&template.emb_cfg, dim, SEED, 2..4));
        let srv_a = PsServer::bind(ps_a, "127.0.0.1:0", &template.emb_cfg, SEED)
            .unwrap()
            .spawn()
            .unwrap();
        let srv_b = PsServer::bind(ps_b, "127.0.0.1:0", &template.emb_cfg, SEED)
            .unwrap()
            .spawn()
            .unwrap();
        let shard_addrs = format!("{},{}", srv_a.addr(), srv_b.addr());

        // The embedding-worker service, exactly as the standalone process
        // builds it: a ShardedRemotePs over both shards behind one worker.
        let mut ew_trainer = preset_trainer(mode, steps, 1, 1);
        let sharded =
            ShardedRemotePs::connect(&ServiceConfig::at(shard_addrs.clone())).unwrap();
        ew_trainer.ps_backend = Some(Arc::new(sharded));
        let ew = EmbWorkerConfig { addr: "127.0.0.1:0".into(), ..EmbWorkerConfig::default() };
        let ew_srv = EmbeddingWorkerServer::for_trainer(
            &ew_trainer,
            &ew,
            Some(&shard_addrs),
            false,
            None,
        )
        .unwrap()
        .spawn()
        .unwrap();

        // The trainer, reaching embeddings only through the tier.
        let mut t = preset_trainer(mode, steps, 1, 1);
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let tier = RemoteEmbTier::connect(
            &ServiceConfig::at(ew_srv.addr().to_string()),
            expect_of(&t),
            t.train.compress,
            net,
        )
        .unwrap();
        t.emb_comm = Some(Arc::new(tier));
        let remote = t.run_rust().unwrap();

        assert_eq!(
            baseline.tracker.losses.len(),
            remote.tracker.losses.len(),
            "{mode:?}"
        );
        for ((sa, la), (sb, lb)) in
            baseline.tracker.losses.iter().zip(&remote.tracker.losses)
        {
            assert_eq!(sa, sb, "{mode:?}");
            assert!(
                (la - lb).abs() <= 1e-6,
                "{mode:?} step {sa}: loss {la} (inline) vs {lb} (remote tier)"
            );
        }
        let auc_a = baseline.report.final_auc.unwrap();
        let auc_b = remote.report.final_auc.unwrap();
        assert!(
            (auc_a - auc_b).abs() <= 1e-6,
            "{mode:?}: AUC {auc_a} (inline) vs {auc_b} (remote tier)"
        );
        for (a, b) in baseline.final_params.iter().zip(&remote.final_params) {
            assert!((a - b).abs() <= 1e-6, "{mode:?}: final params diverged: {a} vs {b}");
        }

        ew_srv.shutdown().unwrap();
        srv_a.shutdown().unwrap();
        srv_b.shutdown().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Real child processes.
// ---------------------------------------------------------------------------

/// A spawned `persia` child with its stdout+stderr streamed into a line
/// buffer (so pipes never fill) and kill-on-drop reaping.
struct Proc {
    child: Child,
    lines: Arc<Mutex<Vec<String>>>,
    readers: Vec<JoinHandle<()>>,
}

impl Proc {
    fn spawn(args: &[String]) -> Proc {
        let exe = env!("CARGO_BIN_EXE_persia");
        let mut child = Command::new(exe)
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn persia child");
        let lines = Arc::new(Mutex::new(Vec::new()));
        let mut readers = Vec::new();
        let stdout = child.stdout.take().expect("stdout piped");
        let stderr = child.stderr.take().expect("stderr piped");
        for reader in [Box::new(stdout) as Box<dyn std::io::Read + Send>, Box::new(stderr)] {
            let lines = lines.clone();
            readers.push(std::thread::spawn(move || {
                let buf = std::io::BufReader::new(reader);
                for line in buf.lines() {
                    match line {
                        Ok(l) => lines.lock().unwrap().push(l),
                        Err(_) => break,
                    }
                }
            }));
        }
        Proc { child, lines, readers }
    }

    /// First buffered line containing `pat`, waiting up to `timeout`.
    fn wait_for_line(&mut self, pat: &str, timeout: Duration) -> Option<String> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(l) =
                self.lines.lock().unwrap().iter().find(|l| l.contains(pat)).cloned()
            {
                return Some(l);
            }
            if Instant::now() >= deadline {
                return None;
            }
            if let Ok(Some(_)) = self.child.try_wait() {
                // Child exited; drain whatever the readers still push.
                std::thread::sleep(Duration::from_millis(100));
                return self
                    .lines
                    .lock()
                    .unwrap()
                    .iter()
                    .find(|l| l.contains(pat))
                    .cloned();
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Wait for exit up to `timeout`.
    fn wait_timeout(&mut self, timeout: Duration) -> Option<ExitStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.child.try_wait().expect("try_wait") {
                Some(status) => return Some(status),
                None if Instant::now() >= deadline => return None,
                None => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    fn output_snapshot(&self) -> String {
        self.lines.lock().unwrap().join("\n")
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        self.kill();
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
    }
}

/// Extract the address from a `... listening on ADDR ...` line.
fn addr_from(line: &str) -> String {
    line.split("listening on ")
        .nth(1)
        .and_then(|r| r.split_whitespace().next())
        .expect("address in listening line")
        .to_string()
}

/// Spawn one `persia serve-ps` shard and wait for its listening line.
fn spawn_ps(node_range: Option<&str>) -> (Proc, String) {
    let mut args: Vec<String> =
        ["serve-ps", "--preset", PRESET, "--dense", DENSE, "--addr", "127.0.0.1:0"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    args.extend(["--shard-capacity".to_string(), CAPACITY.to_string()]);
    args.extend(["--seed".to_string(), SEED.to_string()]);
    if let Some(r) = node_range {
        args.extend(["--node-range".to_string(), r.to_string()]);
    }
    let mut p = Proc::spawn(&args);
    let line = p
        .wait_for_line("listening on ", Duration::from_secs(30))
        .unwrap_or_else(|| panic!("serve-ps never listened:\n{}", p.output_snapshot()));
    let addr = addr_from(&line);
    (p, addr)
}

/// The train-loop flags every process of one deployment must share.
fn shared_flags(steps: usize, nn_workers: usize, emb_workers: usize) -> Vec<String> {
    [
        "--preset",
        PRESET,
        "--dense",
        DENSE,
        "--engine",
        "rust",
        "--mode",
        "sync",
        "--deterministic",
        "true",
        "--netsim",
        "false",
        "--compress",
        "false",
        "--lr",
        "0.05",
        "--tau",
        "4",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([
        "--shard-capacity".to_string(),
        CAPACITY.to_string(),
        "--seed".to_string(),
        SEED.to_string(),
        "--batch".to_string(),
        BATCH.to_string(),
        "--steps".to_string(),
        steps.to_string(),
        "--eval-every".to_string(),
        steps.to_string(),
        "--nn-workers".to_string(),
        nn_workers.to_string(),
        "--emb-workers".to_string(),
        emb_workers.to_string(),
    ])
    .collect()
}

/// Spawn one `persia serve-embedding-worker` and wait for its address.
fn spawn_ew(
    steps: usize,
    nn_workers: usize,
    emb_workers: usize,
    ew_rank: usize,
    remote_ps: &str,
) -> (Proc, String) {
    let mut args = vec!["serve-embedding-worker".to_string()];
    args.extend(shared_flags(steps, nn_workers, emb_workers));
    args.extend([
        "--addr".to_string(),
        "127.0.0.1:0".to_string(),
        "--ew-rank".to_string(),
        ew_rank.to_string(),
        "--remote-ps".to_string(),
        remote_ps.to_string(),
    ]);
    let mut p = Proc::spawn(&args);
    let line = p
        .wait_for_line("embedding worker listening on ", Duration::from_secs(30))
        .unwrap_or_else(|| {
            panic!("serve-embedding-worker never listened:\n{}", p.output_snapshot())
        });
    let addr = addr_from(&line);
    (p, addr)
}

fn parse_losses(output: &str) -> Vec<(u64, f32)> {
    let line = output
        .lines()
        .find(|l| l.starts_with("LOSSES "))
        .unwrap_or_else(|| panic!("no LOSSES line in:\n{output}"));
    line["LOSSES ".len()..]
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|pair| {
            let (s, l) = pair.split_once(':').expect("step:loss pair");
            (s.parse().unwrap(), l.parse().unwrap())
        })
        .collect()
}

fn parse_parity(output: &str) -> (f32, f64) {
    let line = output
        .lines()
        .find(|l| l.starts_with("PARITY "))
        .unwrap_or_else(|| panic!("no PARITY line in:\n{output}"));
    let mut loss = f32::NAN;
    let mut auc = f64::NAN;
    for field in line["PARITY ".len()..].split_whitespace() {
        if let Some(v) = field.strip_prefix("final_loss=") {
            loss = v.parse().unwrap();
        }
        if let Some(v) = field.strip_prefix("final_auc=") {
            auc = v.parse().unwrap_or(f64::NAN);
        }
    }
    (loss, auc)
}

/// Full three-tier deployment with real child processes: 2 `serve-ps`
/// shards × 1 `serve-embedding-worker` × 1 `persia train` — losses and AUC
/// within 1e-6 of the inline single-process run.
#[test]
fn three_tier_child_processes_match_inline() {
    let steps = 30;
    let baseline = preset_trainer(TrainMode::FullSync, steps, 1, 1).run_rust().unwrap();
    let base_auc = baseline.report.final_auc.unwrap();

    let (_ps0, addr0) = spawn_ps(Some("0..2"));
    let (_ps1, addr1) = spawn_ps(Some("2..4"));
    let remote = format!("{addr0},{addr1}");
    let (_ew, ew_addr) = spawn_ew(steps, 1, 1, 0, &remote);

    let mut args = vec!["train".to_string()];
    args.extend(shared_flags(steps, 1, 1));
    args.extend([
        "--embedding-workers".to_string(),
        ew_addr,
        "--parity-lines".to_string(),
        "true".to_string(),
    ]);
    let mut train = Proc::spawn(&args);
    let status = train
        .wait_timeout(Duration::from_secs(300))
        .unwrap_or_else(|| panic!("train hung:\n{}", train.output_snapshot()));
    std::thread::sleep(Duration::from_millis(200));
    assert!(status.success(), "train failed:\n{}", train.output_snapshot());

    let out = train.output_snapshot();
    let losses = parse_losses(&out);
    assert_eq!(losses.len(), baseline.tracker.losses.len());
    for ((sa, la), (sb, lb)) in baseline.tracker.losses.iter().zip(&losses) {
        assert_eq!(sa, sb);
        assert!(
            (la - lb).abs() <= 1e-6,
            "step {sa}: loss {la} (inline) vs {lb} (three-tier processes)"
        );
    }
    let (final_loss, final_auc) = parse_parity(&out);
    assert!(
        (baseline.report.final_loss - final_loss).abs() <= 1e-6,
        "final loss {} (inline) vs {final_loss} (three-tier)",
        baseline.report.final_loss
    );
    assert!(
        (base_auc - final_auc).abs() <= 1e-6,
        "AUC {base_auc} (inline) vs {final_auc} (three-tier)"
    );
}

/// Send a signal (e.g. `-STOP` / `-CONT`) to a spawned child.
fn signal(child: &Child, sig: &str) {
    let ok = Command::new("kill")
        .args([sig, &child.id().to_string()])
        .status()
        .map(|s| s.success())
        .unwrap_or(false);
    assert!(ok, "kill {sig} {} failed", child.id());
}

/// THE elastic-membership acceptance drill (ISSUE 8): SIGKILL one
/// embedding-worker process mid-run with `--ew-failover true`. Rank 1's
/// tier marks its worker dead after the retry budget, the survivor adopts
/// rank 1 (ADOPT_RANK fast-forwards the deterministic loader stream) and
/// re-buffers the in-flight gradient push, both `train-worker` ranks run
/// to completion, and the loss curve + final loss/AUC land within 1e-6 of
/// the unkilled inline baseline — the §4.2.4 claim that embedding workers
/// are parameter-stateless and therefore lossless to replace.
#[test]
fn sigkill_embedding_worker_survives_via_failover_to_parity() {
    let steps = 400;
    let baseline = preset_trainer(TrainMode::FullSync, steps, 2, 2).run_rust().unwrap();
    let base_auc = baseline.report.final_auc.unwrap();

    let (_ps0, addr0) = spawn_ps(Some("0..2"));
    let (_ps1, addr1) = spawn_ps(Some("2..4"));
    let remote = format!("{addr0},{addr1}");
    let (_ew0, ew0_addr) = spawn_ew(steps, 2, 2, 0, &remote);
    let (mut ew1, ew1_addr) = spawn_ew(steps, 2, 2, 1, &remote);
    let ew_list = format!("{ew0_addr},{ew1_addr}");

    let worker_args = |rank: usize, rendezvous: &str| -> Vec<String> {
        let mut args = vec![
            "train-worker".to_string(),
            "--rank".to_string(),
            rank.to_string(),
            "--world".to_string(),
            "2".to_string(),
            "--rendezvous".to_string(),
            rendezvous.to_string(),
            // Must outlast the failover stall (--ew-retries x --ew-retry-ms
            // of redials, then the adoption fast-forward) that rank 1 rides
            // out while rank 0 waits at the AllReduce barrier.
            "--ring-timeout-ms".to_string(),
            "15000".to_string(),
        ];
        args.extend(shared_flags(steps, 2, 2));
        args.extend([
            "--embedding-workers".to_string(),
            ew_list.clone(),
            "--ew-failover".to_string(),
            "true".to_string(),
        ]);
        args
    };

    let mut w0 = Proc::spawn(&worker_args(0, "127.0.0.1:0"));
    let rdzv_line = w0
        .wait_for_line("rendezvous listening on ", Duration::from_secs(60))
        .unwrap_or_else(|| panic!("rank 0 never printed rendezvous:\n{}", w0.output_snapshot()));
    let rendezvous = rdzv_line
        .split("rendezvous listening on ")
        .nth(1)
        .and_then(|r| r.split_whitespace().next())
        .expect("rendezvous address")
        .to_string();
    let mut w1 = Proc::spawn(&worker_args(1, &rendezvous));

    w0.wait_for_line("ring connected: rank 0/2", Duration::from_secs(60))
        .unwrap_or_else(|| panic!("ring never formed:\n{}", w0.output_snapshot()));

    // Freeze both ranks so the SIGKILL provably lands mid-run (a loopback
    // run this small could otherwise finish before the signal), kill the
    // worker serving rank 1, then resume.
    signal(&w0.child, "-STOP");
    signal(&w1.child, "-STOP");
    std::thread::sleep(Duration::from_millis(300));
    ew1.kill();
    signal(&w0.child, "-CONT");
    signal(&w1.child, "-CONT");

    let s0 = w0.wait_timeout(Duration::from_secs(300)).unwrap_or_else(|| {
        panic!("rank 0 hung after embedding-worker SIGKILL:\n{}", w0.output_snapshot())
    });
    let s1 = w1.wait_timeout(Duration::from_secs(300)).unwrap_or_else(|| {
        panic!("rank 1 hung after embedding-worker SIGKILL:\n{}", w1.output_snapshot())
    });
    std::thread::sleep(Duration::from_millis(200));
    assert!(s0.success(), "rank 0 failed:\n{}", w0.output_snapshot());
    assert!(
        s1.success(),
        "rank 1 must survive its embedding worker dying:\n{}",
        w1.output_snapshot()
    );
    assert!(
        w1.output_snapshot().contains("ew-failover"),
        "rank 1 should report the reassignment:\n{}",
        w1.output_snapshot()
    );

    // Parity with the unkilled inline run: every loss + final loss/AUC.
    let out0 = w0.output_snapshot();
    let losses = parse_losses(&out0);
    assert_eq!(losses.len(), baseline.tracker.losses.len());
    for ((sa, la), (sb, lb)) in baseline.tracker.losses.iter().zip(&losses) {
        assert_eq!(sa, sb);
        assert!(
            (la - lb).abs() <= 1e-6,
            "step {sa}: loss {la} (unkilled inline) vs {lb} (failover run)"
        );
    }
    let (final_loss, final_auc) = parse_parity(&out0);
    assert!(
        (baseline.report.final_loss - final_loss).abs() <= 1e-6,
        "final loss {} (unkilled inline) vs {final_loss} (failover run)",
        baseline.report.final_loss
    );
    assert!(
        (base_auc - final_auc).abs() <= 1e-6,
        "AUC {base_auc} (unkilled inline) vs {final_auc} (failover run)"
    );
    // Drop reaps every remaining child.
}

/// An embedding worker started with different flags (here: --steps 41) is
/// rejected at the INFO handshake by the config-fingerprint policy.
#[test]
fn mismatched_embedding_worker_rejected_at_handshake() {
    let steps = 40;
    let (_ps, ps_addr) = spawn_ps(None);
    // Same PS flags (so the worker's own PS handshake passes), different
    // train length.
    let (_ew, ew_addr) = spawn_ew(41, 1, 1, 0, &ps_addr);

    let mut args = vec!["train".to_string()];
    args.extend(shared_flags(steps, 1, 1));
    args.extend(["--embedding-workers".to_string(), ew_addr]);
    let mut train = Proc::spawn(&args);
    let status = train
        .wait_timeout(Duration::from_secs(120))
        .unwrap_or_else(|| panic!("train hung on mismatch:\n{}", train.output_snapshot()));
    std::thread::sleep(Duration::from_millis(200));
    assert!(!status.success(), "mismatched tier must be rejected");
    assert!(
        train.output_snapshot().contains("fingerprint"),
        "rejection should cite the fingerprint:\n{}",
        train.output_snapshot()
    );
}
