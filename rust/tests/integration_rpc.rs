//! Multi-process-shaped integration: the embedding PS served over TCP RPC
//! using the zero-copy wire format — the paper's point-to-point protocol
//! (§4.2.3) running over a real socket.

use std::sync::Arc;

use persia::comm::rpc::{RpcClient, RpcServer};
use persia::comm::transport::TcpTransport;
use persia::comm::wire::{WireReader, WireWriter};
use persia::config::{EmbeddingConfig, OptimizerKind, PartitionPolicy};
use persia::embedding::EmbeddingPs;

/// Message kinds of the PS wire protocol.
const KIND_GET: u32 = 1;
const KIND_PUT: u32 = 2;

fn ps() -> Arc<EmbeddingPs> {
    let cfg = EmbeddingConfig {
        rows_per_group: 1 << 20,
        shard_capacity: 4096,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Sgd,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.5,
    };
    Arc::new(EmbeddingPs::new(&cfg, 8, 77))
}

/// Serve GET/PUT for one connection.
fn serve(ps: Arc<EmbeddingPs>, listener: std::net::TcpListener) {
    let (stream, _) = listener.accept().unwrap();
    let transport = TcpTransport::new(stream);
    let mut server = RpcServer::new();
    let dim = ps.dim();
    {
        let ps = ps.clone();
        server.register(
            KIND_GET,
            Box::new(move |msg| {
                let r = WireReader::parse(msg)?;
                let groups = r.u64(0)?;
                let ids = r.u64(1)?;
                let keys: Vec<(u32, u64)> =
                    groups.iter().zip(&ids).map(|(&g, &id)| (g as u32, id)).collect();
                let mut rows = vec![0.0f32; keys.len() * dim];
                ps.get_many(&keys, &mut rows);
                let mut w = WireWriter::new(KIND_GET);
                w.put_f32(&rows);
                Ok(w.finish())
            }),
        );
    }
    {
        let ps = ps.clone();
        server.register(
            KIND_PUT,
            Box::new(move |msg| {
                let r = WireReader::parse(msg)?;
                let groups = r.u64(0)?;
                let ids = r.u64(1)?;
                let grads = r.f32(2)?;
                let keys: Vec<(u32, u64)> =
                    groups.iter().zip(&ids).map(|(&g, &id)| (g as u32, id)).collect();
                ps.put_grads(&keys, &grads);
                let w = WireWriter::new(KIND_PUT);
                Ok(w.finish())
            }),
        );
    }
    server.serve(&transport).unwrap();
}

#[test]
fn embedding_ps_get_put_over_tcp_matches_local() {
    let ps_remote = ps();
    let ps_local = ps(); // same seed => identical materialization
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let ps_srv = ps_remote.clone();
    let server = std::thread::spawn(move || serve(ps_srv, listener));

    let client = RpcClient::new(TcpTransport::connect(&addr.to_string()).unwrap());
    let dim = 8;
    let keys: Vec<(u32, u64)> = (0..32).map(|i| (i % 4, (i * 37) as u64)).collect();
    let groups: Vec<u64> = keys.iter().map(|&(g, _)| g as u64).collect();
    let ids: Vec<u64> = keys.iter().map(|&(_, id)| id).collect();

    // GET over TCP.
    let mut w = WireWriter::new(KIND_GET);
    w.put_u64(&groups).put_u64(&ids);
    let resp = client.call(&w.finish()).unwrap();
    let remote_rows = WireReader::parse(&resp).unwrap().f32(0).unwrap();

    // Same GET locally.
    let mut local_rows = vec![0.0f32; keys.len() * dim];
    ps_local.get_many(&keys, &mut local_rows);
    assert_eq!(remote_rows, local_rows, "remote PS must materialize identically");

    // PUT over TCP, then re-GET and compare against a local put.
    let grads = vec![1.0f32; keys.len() * dim];
    let mut w = WireWriter::new(KIND_PUT);
    w.put_u64(&groups).put_u64(&ids).put_f32(&grads);
    client.call(&w.finish()).unwrap();
    ps_local.put_grads(&keys, &grads);

    let mut w = WireWriter::new(KIND_GET);
    w.put_u64(&groups).put_u64(&ids);
    let resp = client.call(&w.finish()).unwrap();
    let remote_after = WireReader::parse(&resp).unwrap().f32(0).unwrap();
    let mut local_after = vec![0.0f32; keys.len() * dim];
    ps_local.get_many(&keys, &mut local_after);
    assert_eq!(remote_after, local_after);

    drop(client);
    server.join().unwrap();
}

#[test]
fn tcp_ps_sustains_many_roundtrips() {
    let ps_remote = ps();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || serve(ps_remote, listener));
    let client = RpcClient::new(TcpTransport::connect(&addr.to_string()).unwrap());
    for round in 0..200u64 {
        let mut w = WireWriter::new(KIND_GET);
        w.put_u64(&[round % 4]).put_u64(&[round * 13]);
        let resp = client.call(&w.finish()).unwrap();
        let rows = WireReader::parse(&resp).unwrap().f32(0).unwrap();
        assert_eq!(rows.len(), 8);
    }
    drop(client);
    server.join().unwrap();
}
