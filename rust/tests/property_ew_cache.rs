//! ISSUE-10 property suite for the embedding-worker bounded-staleness
//! cache.
//!
//! * **Deterministic no-op**: deterministic mode never constructs a cache
//!   (`Trainer::ew_cache_params` returns `None`), so a cache-on and a
//!   cache-off run are the same program — asserted bitwise on the loss
//!   curve and the final report.
//! * **SGD mirror parity**: in non-deterministic FullSync with a single
//!   writer, the mirror push policy keeps every cached row bitwise equal to
//!   the PS copy — a cached run reproduces the uncached loss curve exactly.
//! * **Staleness bound, model-checked**: a versioned fake PS stamps every
//!   row with the tick it was read at; driving `EmbCache::fetch_through`
//!   through hundreds of ticks (with stale refreshes and an epoch bump in
//!   the middle) must never serve a row older than the configured bound,
//!   and never a value the PS did not hold.

use std::sync::atomic::{AtomicU64, Ordering};

use persia::config::{
    BenchPreset, ClusterConfig, NetModelConfig, OptimizerKind, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;
use persia::service::{PsBackend, PsStats};
use persia::worker::{EmbCache, EwCacheConfig, EwCacheParams, PushPolicy};

fn small_trainer(deterministic: bool, optimizer: OptimizerKind) -> Trainer {
    let preset = BenchPreset::by_name("taobao").unwrap();
    let model = preset.model("tiny");
    let mut emb_cfg = preset.embedding(&model, 65536);
    emb_cfg.optimizer = optimizer;
    let rows = preset.embedding(&model, 1).rows_per_group;
    let cluster =
        ClusterConfig { n_nn_workers: 1, n_emb_workers: 1, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: 16,
        lr: 0.05,
        staleness_bound: 4,
        steps: 24,
        eval_every: 24,
        seed: 42,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, rows, preset.zipf_exponent, 42);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = deterministic;
    t
}

fn run_losses(t: &Trainer) -> (Vec<(u64, f32)>, f32, f64) {
    let out = t.run_rust().unwrap();
    (out.tracker.losses.clone(), out.report.final_loss, out.report.final_auc.unwrap())
}

/// Deterministic mode force-disables the cache regardless of the knob, so
/// cache-on ≡ cache-off bitwise — the guarantee every deterministic parity
/// suite in this repo leans on.
#[test]
fn deterministic_mode_is_bitwise_cache_invariant() {
    let mut on = small_trainer(true, OptimizerKind::Adagrad);
    on.ew_cache = Some(EwCacheConfig::default());
    assert!(
        on.ew_cache_params().is_none(),
        "deterministic mode must never construct a worker cache"
    );
    let mut off = small_trainer(true, OptimizerKind::Adagrad);
    off.ew_cache = None;

    let (l_on, fl_on, auc_on) = run_losses(&on);
    let (l_off, fl_off, auc_off) = run_losses(&off);
    assert_eq!(l_on, l_off, "deterministic loss curves must be bitwise equal");
    assert_eq!(fl_on.to_bits(), fl_off.to_bits());
    assert_eq!(auc_on.to_bits(), auc_off.to_bits());

    // And the knob is live outside deterministic mode.
    let live = small_trainer(false, OptimizerKind::Adagrad);
    assert!(live.ew_cache_params().is_some(), "the cache defaults on in async modes");
}

/// Single-writer SGD: the mirror policy replays exactly the PS's own
/// stateless update on the cached copy, so a cached non-deterministic
/// FullSync run reproduces the uncached loss curve bitwise.
#[test]
fn sgd_mirror_reproduces_the_uncached_run_exactly() {
    let mut off = small_trainer(false, OptimizerKind::Sgd);
    off.ew_cache = None;
    let mut on = small_trainer(false, OptimizerKind::Sgd);
    on.ew_cache = Some(EwCacheConfig::default());
    match on.ew_cache_params().expect("cache on").push {
        PushPolicy::MirrorSgd { .. } => {}
        p => panic!("SGD must resolve to the mirror policy, got {p:?}"),
    }

    let (l_off, fl_off, auc_off) = run_losses(&off);
    let (l_on, fl_on, auc_on) = run_losses(&on);
    assert_eq!(l_on, l_off, "SGD-mirrored cache must not perturb the loss curve");
    assert_eq!(fl_on.to_bits(), fl_off.to_bits());
    assert_eq!(auc_on.to_bits(), auc_off.to_bits());
}

// ---------------------------------------------------------------------------
// Staleness bound, model-checked against a versioned PS
// ---------------------------------------------------------------------------

const DIM: usize = 4;

/// A PS whose rows encode `(id, version-at-read)` — the reference model the
/// cache is checked against. Bumping `epoch` models a committed reshard.
struct VersionedPs {
    version: AtomicU64,
    epoch: AtomicU64,
}

impl PsBackend for VersionedPs {
    fn dim(&self) -> usize {
        DIM
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> anyhow::Result<()> {
        let v = self.version.load(Ordering::SeqCst);
        for (i, &(_, id)) in keys.iter().enumerate() {
            let row = &mut out[i * DIM..(i + 1) * DIM];
            row[0] = id as f32;
            row[1] = v as f32;
            row[2] = 0.0;
            row[3] = 0.0;
        }
        Ok(())
    }

    fn put_grads(&self, _keys: &[(u32, u64)], _grads: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn stats(&self) -> anyhow::Result<PsStats> {
        Ok(PsStats::default())
    }

    fn routing_epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }
}

/// Drive 300 fetch ticks of skewed traffic through the cache and assert
/// the bound: every served row is a value the PS held within the last `S`
/// ticks, and an epoch bump refreshes everything at once. Capacity exceeds
/// the key universe so entries live long enough for the bound (not the
/// evictor — cache.rs unit tests cover that) to be what expires them.
#[test]
fn served_rows_never_exceed_the_staleness_bound() {
    const S: u64 = 5;
    const TICKS: u64 = 300;
    const BUMP_AT: u64 = 150;
    let ps = VersionedPs { version: AtomicU64::new(0), epoch: AtomicU64::new(0) };
    let cache = EmbCache::new(
        EwCacheParams {
            capacity: 64,
            staleness_ticks: S,
            admit_threshold: 1,
            push: PushPolicy::Invalidate,
        },
        DIM,
    );

    let mut rng: u64 = 0x9e3779b97f4a7c15;
    let mut rows = vec![0.0f32; 8 * DIM];
    for tick in 0..TICKS {
        // The PS advances one version per tick; the cache clock advances one
        // tick per fetch_through call, so versions and ticks stay aligned.
        ps.version.store(tick, Ordering::SeqCst);
        if tick == BUMP_AT {
            ps.epoch.store(1, Ordering::SeqCst);
        }
        let keys: Vec<(u32, u64)> = (0..8)
            .map(|_| {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                // Zipf-ish: half the draws land in an 8-key hot set.
                let id = if rng & 1 == 0 { (rng >> 8) % 8 } else { (rng >> 8) % 32 };
                (0u32, id)
            })
            .collect();
        cache.fetch_through(&ps, &keys, &mut rows).unwrap();
        for (slot, &(_, id)) in keys.iter().enumerate() {
            let row = &rows[slot * DIM..(slot + 1) * DIM];
            assert_eq!(row[0] as u64, id, "tick {tick}: row served for the wrong key");
            let served = row[1] as u64;
            assert!(
                served <= tick && tick - served <= S,
                "tick {tick}: served version {served} exceeds the staleness bound {S}"
            );
            if tick >= BUMP_AT {
                assert!(
                    served >= BUMP_AT,
                    "tick {tick}: row from before the epoch bump survived the flush \
                     (version {served})"
                );
            }
        }
    }
    let s = cache.stats();
    assert!(s.hits > 0, "the hot set never hit: {s:?}");
    assert!(s.stale_refreshes > 0, "the bound never expired an entry: {s:?}");
    assert!(s.flushes >= 1, "the epoch bump never flushed: {s:?}");
}
