//! Loopback integration of the TCP service mode: an [`EmbeddingPs`] served
//! over a real socket by [`PsServer`], trained against through the
//! [`RemotePs`] backend, and compared with the in-process backend.
//!
//! No test here sleeps: ordering comes from blocking RPC calls, channel
//! joins, and the deterministic trainer mode (inline gradient application
//! with the prefetch pipeline intact).

use std::sync::Arc;

use persia::config::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, ServiceConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::embedding::EmbeddingPs;
use persia::hybrid::Trainer;
use persia::service::{PsBackend, PsServer, PsServerHandle, RemotePs};

fn base_trainer(mode: TrainMode, steps: usize, nn_workers: usize) -> Trainer {
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 2,
        emb_dim_per_group: 8,
        nid_dim: 4,
        hidden: vec![16, 8],
        ids_per_group: 2,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 500,
        shard_capacity: 4096,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster = ClusterConfig {
        n_nn_workers: nn_workers,
        n_emb_workers: 2,
        net: NetModelConfig::disabled(),
    };
    let train = TrainConfig {
        mode,
        batch_size: 32,
        lr: 0.1,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: 23,
        use_pjrt: false,
        compress: true,
    };
    let dataset = SyntheticDataset::new(&model, 500, 1.05, 23);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.eval_rows = 1024;
    t
}

/// Spawn a PS server configured exactly like `t` would configure its
/// in-process PS, on an ephemeral loopback port.
fn spawn_ps_for(t: &Trainer) -> (PsServerHandle, String) {
    let ps = Arc::new(EmbeddingPs::new(&t.emb_cfg, t.model.emb_dim_per_group, t.train.seed));
    let server = PsServer::bind(ps, "127.0.0.1:0", &t.emb_cfg, t.train.seed).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    (server.spawn().unwrap(), addr)
}

fn connect(addr: &str, wire_compress: bool) -> Arc<RemotePs> {
    let cfg = ServiceConfig {
        addr: addr.to_string(),
        client_conns: 2,
        wire_compress,
        ..ServiceConfig::default()
    };
    Arc::new(RemotePs::connect(&cfg).unwrap())
}

/// The acceptance test: hybrid (and fully synchronous) training through the
/// remote backend reaches the same loss/AUC as the in-process backend within
/// 1e-6 on the deterministic synthetic dataset.
#[test]
fn remote_ps_training_matches_in_process_within_1e6() {
    for mode in [TrainMode::Hybrid, TrainMode::FullSync] {
        let steps = 80;
        // In-process reference run (deterministic: inline grad application).
        let mut local_t = base_trainer(mode, steps, 1);
        local_t.deterministic = true;
        let local = local_t.run_rust().unwrap();

        // Identical run against the PS over TCP.
        let mut remote_t = base_trainer(mode, steps, 1);
        remote_t.deterministic = true;
        let (handle, addr) = spawn_ps_for(&remote_t);
        let backend = connect(&addr, false);
        remote_t.ps_backend = Some(backend.clone());
        let remote = remote_t.run_rust().unwrap();

        let auc_local = local.report.final_auc.unwrap();
        let auc_remote = remote.report.final_auc.unwrap();
        assert!(
            (auc_local - auc_remote).abs() <= 1e-6,
            "{mode:?}: AUC {auc_local} (local) vs {auc_remote} (remote)"
        );
        // The run is meaningful: the loss actually moved.
        let early: f32 = local.tracker.losses[..10].iter().map(|(_, l)| l).sum::<f32>() / 10.0;
        let late = local.tracker.recent_loss(10).unwrap();
        assert!(late < early, "{mode:?}: reference run did not learn ({early} -> {late})");
        assert_eq!(local.tracker.losses.len(), remote.tracker.losses.len());
        for ((sa, la), (sb, lb)) in local.tracker.losses.iter().zip(&remote.tracker.losses) {
            assert_eq!(sa, sb);
            assert!((la - lb).abs() <= 1e-6, "{mode:?} step {sa}: loss {la} vs {lb}");
        }

        // Graceful teardown: drop clients, then drain the server.
        drop(remote_t);
        drop(backend);
        handle.shutdown().unwrap();
    }
}

/// All four synchronization modes run unchanged against a remote PS,
/// including the concurrent paths (async appliers + multiple NN workers
/// sharing the client pool).
#[test]
fn all_four_modes_train_against_remote_ps() {
    for mode in TrainMode::ALL {
        let steps = 60;
        let mut t = base_trainer(mode, steps, 2);
        t.train.eval_every = 0;
        let (handle, addr) = spawn_ps_for(&t);
        let backend = connect(&addr, false);
        t.ps_backend = Some(backend.clone());
        let out = t.run_rust().unwrap();
        assert_eq!(out.report.steps, steps as u64);
        let early: f32 = out.tracker.losses[..10].iter().map(|(_, l)| l).sum::<f32>() / 10.0;
        let late = out.tracker.recent_loss(10).unwrap();
        assert!(late < early, "{mode:?}: loss did not drop over remote PS ({early} -> {late})");

        // The remote PS actually materialized and served rows.
        let stats = backend.stats().unwrap();
        assert!(stats.total_rows > 0, "{mode:?}: PS saw no traffic");
        assert!(out.ps_imbalance.is_finite(), "{mode:?}: stats RPC failed");

        drop(t);
        drop(backend);
        handle.shutdown().unwrap();
    }
}

/// The lossy fp16 wire compression halves PS traffic but must not break
/// convergence: AUC stays close to the exact-wire run.
#[test]
fn wire_compression_converges_close_to_exact() {
    let steps = 120;
    let run = |wire_compress: bool| {
        let mut t = base_trainer(TrainMode::Hybrid, steps, 1);
        t.deterministic = true;
        let (handle, addr) = spawn_ps_for(&t);
        let backend = connect(&addr, wire_compress);
        t.ps_backend = Some(backend.clone());
        let out = t.run_rust().unwrap();
        drop(t);
        drop(backend);
        handle.shutdown().unwrap();
        out.report.final_auc.unwrap()
    };
    let exact = run(false);
    let lossy = run(true);
    assert!(
        (exact - lossy).abs() < 0.03,
        "fp16 PS wire broke convergence: {exact} vs {lossy}"
    );
}

/// Graceful shutdown semantics: a SHUTDOWN RPC is acked, in-flight clients
/// finish, and the drained server stops accepting.
#[test]
fn shutdown_is_graceful_and_final() {
    let t = base_trainer(TrainMode::FullSync, 1, 1);
    let (handle, addr) = spawn_ps_for(&t);

    let backend = connect(&addr, false);
    // The server is live: geometry matches the config we gave it.
    assert_eq!(backend.dim(), t.model.emb_dim_per_group);
    assert_eq!(backend.n_nodes(), t.emb_cfg.n_nodes);
    let keys: Vec<(u32, u64)> = (0..16).map(|i| (i % 2, i as u64)).collect();
    let mut rows = vec![0.0f32; 16 * 8];
    backend.get_many(&keys, &mut rows).unwrap();
    backend.put_grads(&keys, &vec![0.5; 16 * 8]).unwrap();
    assert_eq!(backend.stats().unwrap().total_rows, 16);

    // Remote-initiated shutdown: ack arrives before the server stops.
    backend.shutdown_server().unwrap();
    drop(backend);
    handle.shutdown().unwrap();

    // The drained server no longer accepts connections.
    let cfg = ServiceConfig { addr, client_conns: 1, ..ServiceConfig::default() };
    assert!(RemotePs::connect(&cfg).is_err(), "server still accepting after shutdown");
}

/// A trainer whose embedding config/seed doesn't match the server's fails
/// the handshake loudly instead of silently training different numerics.
#[test]
fn mismatched_trainer_config_is_rejected() {
    let server_side = base_trainer(TrainMode::Hybrid, 10, 1);
    let (handle, addr) = spawn_ps_for(&server_side);

    // Same geometry (dim/nodes/shards) but a different seed: rows would
    // materialize differently server-side.
    let mut t = base_trainer(TrainMode::Hybrid, 10, 1);
    t.train.seed += 1;
    t.dataset = SyntheticDataset::new(&t.model, 500, 1.05, t.train.seed);
    let backend = connect(&addr, false);
    t.ps_backend = Some(backend.clone());
    let err = t.run_rust().unwrap_err();
    assert!(
        format!("{err:#}").contains("config mismatch"),
        "wrong error for seed mismatch: {err:#}"
    );

    // A matching trainer on the same server still passes the handshake.
    let mut ok = base_trainer(TrainMode::Hybrid, 10, 1);
    ok.ps_backend = Some(backend.clone());
    ok.run_rust().unwrap();

    drop(t);
    drop(ok);
    drop(backend);
    handle.shutdown().unwrap();
}

/// A second client sharing the same server sees the first client's updates —
/// the PS really is shared state across processes, not a per-connection copy.
#[test]
fn remote_ps_state_is_shared_across_clients() {
    let t = base_trainer(TrainMode::FullSync, 1, 1);
    let (handle, addr) = spawn_ps_for(&t);
    let a = connect(&addr, false);
    let b = connect(&addr, false);

    let keys = [(0u32, 7u64)];
    let mut before = vec![0.0f32; 8];
    a.get_many(&keys, &mut before).unwrap();
    a.put_grads(&keys, &vec![1.0; 8]).unwrap();

    let mut seen_by_b = vec![0.0f32; 8];
    b.get_many(&keys, &mut seen_by_b).unwrap();
    assert_ne!(before, seen_by_b, "client B must observe client A's update");

    drop(a);
    drop(b);
    handle.shutdown().unwrap();
}
