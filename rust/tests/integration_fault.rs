//! Fault-tolerance integration (paper §4.2.4): inject failures into a live
//! manual training loop and verify each component's recovery policy.

use std::sync::Arc;

use persia::comm::NetSim;
use persia::config::{
    EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy, Pooling,
};
use persia::data::SyntheticDataset;
use persia::dense::{DenseModel, DenseOptimizer, DenseOptimizerKind};
use persia::embedding::checkpoint::CheckpointManager;
use persia::embedding::EmbeddingPs;
use persia::fault::{DenseBackup, PsBackup};
use persia::metrics::auc;
use persia::runtime::DenseEngine;
use persia::util::Rng;
use persia::worker::EmbeddingWorker;

fn setup() -> (ModelConfig, Arc<EmbeddingPs>, Arc<EmbeddingWorker>, SyntheticDataset, DenseEngine)
{
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 4,
        emb_dim_per_group: 8,
        nid_dim: 8,
        hidden: vec![32, 16],
        ids_per_group: 4,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 2000,
        shard_capacity: 8192,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let ps = Arc::new(EmbeddingPs::new(&emb_cfg, model.emb_dim_per_group, 9));
    let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
    let ew = Arc::new(EmbeddingWorker::new(0, ps.clone(), &model, net, false));
    let ds = SyntheticDataset::new(&model, 2000, 1.05, 9);
    let mut rng = Rng::new(1);
    let dm = DenseModel::new(&model.dims(), model.emb_dim(), model.nid_dim, &mut rng);
    let engine = DenseEngine::rust(dm);
    (model, ps, ew, ds, engine)
}

/// One manual hybrid training step; returns (loss, params updated in place).
fn train_step(
    ds: &SyntheticDataset,
    rng: &mut Rng,
    ew: &EmbeddingWorker,
    engine: &DenseEngine,
    params: &mut Vec<f32>,
    opt: &mut DenseOptimizer,
    batch: usize,
) -> anyhow::Result<f32> {
    let b = ds.batch(rng, batch);
    let sids = ew.register(b.ids.clone());
    let (emb, _) = ew.pull(&sids)?;
    let out = engine.train_step(params, &emb, &b.nid, &b.labels)?;
    opt.step(params, &out.grad_flat);
    ew.push_grads(&sids, &out.grad_emb)?;
    Ok(out.loss)
}

fn eval(ds: &SyntheticDataset, ew: &EmbeddingWorker, engine: &DenseEngine, params: &[f32]) -> f64 {
    let tb = ds.test_batch(1536);
    let (emb, _) = ew.lookup_direct(&tb).unwrap();
    let probs = engine.forward(params, &emb, &tb.nid, tb.len()).unwrap();
    auc(&probs, &tb.labels)
}

#[test]
fn ps_crash_with_shared_memory_recovers_losslessly_mid_training() {
    let (model, ps, ew, ds, engine) = setup();
    let mut rng = ds.train_rng(0);
    let mut rngm = Rng::new(2);
    let dm = DenseModel::new(&model.dims(), model.emb_dim(), model.nid_dim, &mut rngm);
    let mut params = dm.params_flat();
    let mut opt = DenseOptimizer::new(DenseOptimizerKind::Sgd, 0.1, params.len());
    let backup = PsBackup::new(2);

    for _ in 0..150 {
        train_step(&ds, &mut rng, &ew, &engine, &mut params, &mut opt, 64).unwrap();
    }
    let auc_before = eval(&ds, &ew, &engine, &params);

    // Process-level PS failure on both nodes; shared memory survives.
    backup.mirror_shared(&ps, 0).unwrap();
    backup.mirror_shared(&ps, 1).unwrap();
    ps.wipe_node(0).unwrap();
    ps.wipe_node(1).unwrap();
    assert_eq!(backup.recover(&ps, 0, true).unwrap(), "shared-memory");
    assert_eq!(backup.recover(&ps, 1, true).unwrap(), "shared-memory");

    let auc_after = eval(&ds, &ew, &engine, &params);
    assert!((auc_before - auc_after).abs() < 1e-9, "{auc_before} vs {auc_after}");

    // Training continues and keeps improving (or at least doesn't collapse).
    for _ in 0..100 {
        train_step(&ds, &mut rng, &ew, &engine, &mut params, &mut opt, 64).unwrap();
    }
    let auc_final = eval(&ds, &ew, &engine, &params);
    assert!(auc_final > auc_before - 0.02, "{auc_before} -> {auc_final}");
}

#[test]
fn ps_crash_without_shared_memory_falls_back_to_disk_checkpoint() {
    let (_model, ps, ew, ds, engine) = setup();
    let mut rng = ds.train_rng(0);
    let mut rngm = Rng::new(3);
    let dm = DenseModel::new(
        &[40, 32, 16, 1],
        32,
        8,
        &mut rngm,
    );
    let mut params = dm.params_flat();
    let mut opt = DenseOptimizer::new(DenseOptimizerKind::Sgd, 0.1, params.len());

    for _ in 0..80 {
        train_step(&ds, &mut rng, &ew, &engine, &mut params, &mut opt, 64).unwrap();
    }
    let dir = std::env::temp_dir().join(format!("persia_it_ckpt_{}", std::process::id()));
    let mgr = CheckpointManager::new(&dir).unwrap();
    mgr.save(&ps).unwrap();
    let auc_at_ckpt = eval(&ds, &ew, &engine, &params);

    for _ in 0..40 {
        train_step(&ds, &mut rng, &ew, &engine, &mut params, &mut opt, 64).unwrap();
    }
    // Crash losing RAM; restore from disk (rolls back post-ckpt puts only).
    ps.wipe_node(0).unwrap();
    ps.wipe_node(1).unwrap();
    mgr.restore(&ps).unwrap();
    let auc_restored = eval(&ds, &ew, &engine, &params);
    assert!(
        (auc_restored - auc_at_ckpt).abs() < 0.03,
        "restored AUC {auc_restored} far from checkpoint AUC {auc_at_ckpt}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn emb_worker_failure_drops_inflight_samples_but_training_continues() {
    let (_m, _ps, ew, ds, engine) = setup();
    let mut rng = ds.train_rng(0);
    let mut rngm = Rng::new(4);
    let dm = DenseModel::new(&[40, 32, 16, 1], 32, 8, &mut rngm);
    let mut params = dm.params_flat();
    let mut opt = DenseOptimizer::new(DenseOptimizerKind::Sgd, 0.1, params.len());

    // In-flight batch registered but not yet trained on.
    let b = ds.batch(&mut rng, 32);
    let sids = ew.register(b.ids.clone());
    assert_eq!(ew.buffered(), 32);

    // Worker dies: buffer abandoned, no recovery (paper policy).
    ew.abandon_buffer();
    assert!(ew.pull(&sids).is_err(), "in-flight samples are lost");

    // The pipeline simply re-dispatches fresh samples.
    let mut losses = Vec::new();
    for _ in 0..60 {
        losses.push(
            train_step(&ds, &mut rng, &ew, &engine, &mut params, &mut opt, 64).unwrap(),
        );
    }
    assert!(losses.last().unwrap() < losses.first().unwrap());
}

#[test]
fn nn_worker_failure_reloads_dense_checkpoint() {
    let (_m, _ps, ew, ds, engine) = setup();
    let mut rng = ds.train_rng(0);
    let mut rngm = Rng::new(5);
    let dm = DenseModel::new(&[40, 32, 16, 1], 32, 8, &mut rngm);
    let mut params = dm.params_flat();
    let mut opt = DenseOptimizer::new(DenseOptimizerKind::Sgd, 0.1, params.len());
    let backup = DenseBackup::new();

    for step in 0..100u64 {
        train_step(&ds, &mut rng, &ew, &engine, &mut params, &mut opt, 64).unwrap();
        if step % 25 == 24 {
            backup.save(step, &params);
        }
    }
    // GPU instance failure: local copy gone; all workers reload checkpoint.
    let corrupted: Vec<f32> = params.iter().map(|_| 0.0).collect();
    params = corrupted;
    let (ckpt_step, ckpt_params) = backup.load().expect("checkpoint exists");
    assert_eq!(ckpt_step, 99);
    params = ckpt_params;

    // Continue training from the checkpoint; AUC recovers above chance.
    for _ in 0..60 {
        train_step(&ds, &mut rng, &ew, &engine, &mut params, &mut opt, 64).unwrap();
    }
    let a = eval(&ds, &ew, &engine, &params);
    assert!(a > 0.55, "post-recovery AUC {a}");
}
