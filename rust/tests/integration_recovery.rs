//! The ISSUE-5 acceptance drills: coordinated checkpoint epochs, resumable
//! training, and mid-run SIGKILL survival — in-process first (fast, exact),
//! then against real `persia` child processes.
//!
//! * checkpoint epochs are pure observation: a run with `--checkpoint-every`
//!   is bit-identical to one without;
//! * `--resume-from` restarts a run from a committed epoch and finishes
//!   bit-identically to the uninterrupted run (dense + optimizer + loader
//!   cursors + PS state all restored);
//! * a two-tier deployment (train × serve-ps ×2) SIGKILLed wholesale
//!   resumes from its last committed epoch to ≤1e-6 parity;
//! * the same wholesale kill with COLD-BACKED shards (`--cold-dir`, a hot
//!   budget far below the working set): the committed epoch carries both
//!   tiers, and the resumed run matches an unkilled all-hot reference;
//! * the tentpole drill: in a 2 PS × 1 EW × 2 NN-rank three-tier run,
//!   SIGKILL of a single PS shard mid-run is *survived* — the recovery
//!   layer re-handshakes the restarted shard (restored from its committed
//!   epoch), replays the gradient-put delta, and training completes within
//!   1e-6 of the unkilled run.

use std::path::PathBuf;

use persia::config::{
    BenchPreset, ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind,
    PartitionPolicy, Pooling, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::hybrid::{ResumeState, Trainer};
use persia::recovery::{latest_epoch, load_manifest, EpochConfig};

fn tmp_dir(tag: &str) -> PathBuf {
    let d =
        std::env::temp_dir().join(format!("persia_rec_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic FullSync single-worker trainer over the in-process PS —
/// the exact-resume configuration (τ = 0, so the resume seam reorders no
/// PS reads relative to writes).
fn small_trainer(steps: usize) -> Trainer {
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 2,
        emb_dim_per_group: 8,
        nid_dim: 4,
        hidden: vec![16, 8],
        ids_per_group: 2,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 500,
        shard_capacity: 4096,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster = ClusterConfig {
        n_nn_workers: 1,
        n_emb_workers: 2,
        net: NetModelConfig::disabled(),
    };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: 16,
        lr: 0.1,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: 21,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, 500, 1.05, 21);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.eval_rows = 512;
    t.deterministic = true;
    t
}

#[test]
fn epoch_checkpointing_is_pure_observation() {
    let base = small_trainer(40).run_rust().unwrap();
    let dir = tmp_dir("observe");
    let mut t = small_trainer(40);
    t.checkpoint = Some(EpochConfig { dir: dir.clone(), every: 10 });
    let ck = t.run_rust().unwrap();
    // Cutting epochs must not change a single bit of the run.
    assert_eq!(base.tracker.losses, ck.tracker.losses);
    assert_eq!(base.tracker.aucs, ck.tracker.aucs);
    assert_eq!(base.final_params, ck.final_params);
    // ...and the epochs it cut are committed and well-formed.
    assert_eq!(latest_epoch(&dir), Some(40));
    let m = load_manifest(&dir, 20).unwrap();
    assert_eq!(m.step, 20);
    assert_eq!(m.world, 1);
    assert_eq!(m.fingerprint, small_trainer(40).config_fingerprint());
    assert!(!m.params.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_from_epoch_matches_uninterrupted_run_exactly() {
    let dir = tmp_dir("resume");
    let full = {
        let mut t = small_trainer(40);
        t.checkpoint = Some(EpochConfig { dir: dir.clone(), every: 10 });
        t.run_rust().unwrap()
    };
    // Resume a FRESH trainer from the middle epoch: dense + optimizer from
    // the manifest, PS from the epoch files, loader by fast-forward.
    let manifest = load_manifest(&dir, 20).unwrap();
    let mut resumed = small_trainer(40);
    resumed.start_step = 20;
    resumed.resume = Some(ResumeState::from_manifest(&manifest, Some(dir.clone())));
    let out = resumed.run_rust().unwrap();

    assert_eq!(out.final_params, full.final_params, "resume diverged from the full run");
    let suffix: Vec<(u64, f32)> =
        full.tracker.losses.iter().filter(|(s, _)| *s >= 20).cloned().collect();
    assert_eq!(out.tracker.losses, suffix, "resumed loss curve != full run's suffix");
    assert_eq!(out.tracker.aucs, full.tracker.aucs);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_resume_state_is_rejected_loudly() {
    let dir = tmp_dir("badresume");
    {
        let mut t = small_trainer(20);
        t.checkpoint = Some(EpochConfig { dir: dir.clone(), every: 10 });
        t.run_rust().unwrap();
    }
    // Wrong parameter count (a manifest from a different model).
    let mut m = load_manifest(&dir, 10).unwrap();
    m.params.pop();
    let mut t = small_trainer(20);
    t.start_step = 10;
    t.resume = Some(ResumeState::from_manifest(&m, Some(dir.clone())));
    let err = t.run_rust().unwrap_err();
    assert!(format!("{err:#}").contains("dense params"), "{err:#}");
    // A start step at/after the configured total is rejected up front.
    let mut t2 = small_trainer(20);
    t2.start_step = 20;
    assert!(t2.run_rust().is_err());
    // A zero checkpoint cadence is rejected up front.
    let mut t3 = small_trainer(20);
    t3.checkpoint = Some(EpochConfig { dir: dir.clone(), every: 0 });
    assert!(t3.run_rust().is_err());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Real child processes: SIGKILL drills.
// ---------------------------------------------------------------------------

mod multiprocess {
    use super::*;
    use std::io::BufRead as _;
    use std::process::{Child, Command, ExitStatus, Stdio};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;
    use std::time::{Duration, Instant};

    const PRESET: &str = "taobao";
    const DENSE: &str = "tiny";
    const CAPACITY: &str = "65536"; // ample: no LRU evictions, exact replay
    const SEED: &str = "42";
    const BATCH: &str = "16";

    /// A spawned `persia` child with stdout+stderr streamed into a line
    /// buffer (so pipes never fill) and kill-on-drop reaping.
    struct Proc {
        child: Child,
        lines: Arc<Mutex<Vec<String>>>,
        readers: Vec<JoinHandle<()>>,
    }

    impl Proc {
        fn spawn(args: &[String]) -> Proc {
            let exe = env!("CARGO_BIN_EXE_persia");
            let mut child = Command::new(exe)
                .args(args)
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .expect("spawn persia child");
            let lines = Arc::new(Mutex::new(Vec::new()));
            let mut readers = Vec::new();
            let stdout = child.stdout.take().expect("stdout piped");
            let stderr = child.stderr.take().expect("stderr piped");
            for reader in
                [Box::new(stdout) as Box<dyn std::io::Read + Send>, Box::new(stderr)]
            {
                let lines = lines.clone();
                readers.push(std::thread::spawn(move || {
                    let buf = std::io::BufReader::new(reader);
                    for line in buf.lines() {
                        match line {
                            Ok(l) => lines.lock().unwrap().push(l),
                            Err(_) => break,
                        }
                    }
                }));
            }
            Proc { child, lines, readers }
        }

        fn wait_for_line(&mut self, pat: &str, timeout: Duration) -> Option<String> {
            let deadline = Instant::now() + timeout;
            loop {
                if let Some(l) =
                    self.lines.lock().unwrap().iter().find(|l| l.contains(pat)).cloned()
                {
                    return Some(l);
                }
                if Instant::now() >= deadline {
                    return None;
                }
                if let Ok(Some(_)) = self.child.try_wait() {
                    std::thread::sleep(Duration::from_millis(100));
                    return self
                        .lines
                        .lock()
                        .unwrap()
                        .iter()
                        .find(|l| l.contains(pat))
                        .cloned();
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }

        fn wait_timeout(&mut self, timeout: Duration) -> Option<ExitStatus> {
            let deadline = Instant::now() + timeout;
            loop {
                match self.child.try_wait().expect("try_wait") {
                    Some(status) => return Some(status),
                    None if Instant::now() >= deadline => return None,
                    None => std::thread::sleep(Duration::from_millis(50)),
                }
            }
        }

        fn output_snapshot(&self) -> String {
            self.lines.lock().unwrap().join("\n")
        }

        fn kill(&mut self) {
            let _ = self.child.kill();
            let _ = self.child.wait();
        }
    }

    impl Drop for Proc {
        fn drop(&mut self) {
            self.kill();
            for r in self.readers.drain(..) {
                let _ = r.join();
            }
        }
    }

    fn strs(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    /// The numeric flags every process of a deployment shares (they ride in
    /// the config fingerprint, so all processes must agree).
    fn shared_flags(steps: usize, nn_workers: usize) -> Vec<String> {
        strs(&[
            "--preset", PRESET, "--dense", DENSE, "--engine", "rust", "--mode", "sync",
            "--deterministic", "true", "--shard-capacity", CAPACITY, "--seed", SEED,
            "--batch", BATCH, "--lr", "0.05", "--tau", "4", "--netsim", "false",
            "--compress", "false", "--emb-workers", "1",
        ])
        .into_iter()
        .chain([
            "--steps".to_string(),
            steps.to_string(),
            "--eval-every".to_string(),
            steps.to_string(),
            "--nn-workers".to_string(),
            nn_workers.to_string(),
        ])
        .collect()
    }

    /// Spawn `persia serve-ps` on `addr` and wait for its listening line,
    /// retrying the spawn (rebinding a just-released port can race the old
    /// socket's teardown — the restart half of the kill drills).
    fn spawn_ps(
        addr: &str,
        node_range: &str,
        steps: usize,
        nn_workers: usize,
        ckpt_dir: &std::path::Path,
        restore_epoch: Option<u64>,
    ) -> (Proc, String) {
        spawn_ps_with(addr, node_range, steps, nn_workers, ckpt_dir, restore_epoch, &[])
    }

    /// [`spawn_ps`] with extra flags appended — the tiered-storage drills
    /// pass the `--cold-dir`/`--hot-capacity` pair through here.
    fn spawn_ps_with(
        addr: &str,
        node_range: &str,
        steps: usize,
        nn_workers: usize,
        ckpt_dir: &std::path::Path,
        restore_epoch: Option<u64>,
        extra: &[String],
    ) -> (Proc, String) {
        for attempt in 0..40u64 {
            let mut args = strs(&["serve-ps", "--addr"]);
            args.push(addr.to_string());
            args.extend(strs(&["--node-range"]));
            args.push(node_range.to_string());
            args.extend(shared_flags(steps, nn_workers));
            args.push("--checkpoint-dir".to_string());
            args.push(ckpt_dir.display().to_string());
            if let Some(step) = restore_epoch {
                args.push("--restore-epoch".to_string());
                args.push(step.to_string());
            }
            args.extend(extra.iter().cloned());
            let mut p = Proc::spawn(&args);
            if let Some(line) = p.wait_for_line("listening on ", Duration::from_secs(30)) {
                let got = line
                    .split("listening on ")
                    .nth(1)
                    .and_then(|r| r.split_whitespace().next())
                    .expect("address in listening line")
                    .to_string();
                return (p, got);
            }
            drop(p);
            std::thread::sleep(Duration::from_millis(100 + 50 * attempt));
        }
        panic!("persia serve-ps would not start on {addr} ({node_range})");
    }

    fn parse_losses(output: &str) -> Vec<(u64, f32)> {
        let line = output
            .lines()
            .find(|l| l.starts_with("LOSSES "))
            .unwrap_or_else(|| panic!("no LOSSES line in:\n{output}"));
        line["LOSSES ".len()..]
            .split(',')
            .filter(|f| !f.is_empty())
            .map(|f| {
                let (s, l) = f.split_once(':').expect("step:loss");
                (s.parse().unwrap(), l.parse().unwrap())
            })
            .collect()
    }

    fn parse_parity(output: &str) -> (f32, f64) {
        let line = output
            .lines()
            .find(|l| l.starts_with("PARITY "))
            .unwrap_or_else(|| panic!("no PARITY line in:\n{output}"));
        let mut loss = f32::NAN;
        let mut auc = f64::NAN;
        for field in line["PARITY ".len()..].split_whitespace() {
            if let Some(v) = field.strip_prefix("final_loss=") {
                loss = v.parse().unwrap();
            }
            if let Some(v) = field.strip_prefix("final_auc=") {
                auc = v.parse().unwrap_or(f64::NAN);
            }
        }
        (loss, auc)
    }

    /// Compare two loss curves on their overlapping steps.
    fn assert_losses_match(got: &[(u64, f32)], want: &[(u64, f32)], what: &str) {
        assert!(!got.is_empty(), "{what}: empty loss curve");
        for (step, loss) in got {
            let (_, ref_loss) = want
                .iter()
                .find(|(s, _)| s == step)
                .unwrap_or_else(|| panic!("{what}: reference has no step {step}"));
            assert!(
                (loss - ref_loss).abs() <= 1e-6,
                "{what}: step {step} loss {loss} vs reference {ref_loss}"
            );
        }
    }

    /// Kill→restart→resume, two-tier: `persia train` against 2 checkpointing
    /// `serve-ps` shards is SIGKILLed wholesale after its first committed
    /// epoch; the shards restart pinned to `LATEST`, `--resume-from`
    /// finishes the run, and the result matches an unkilled deployment
    /// within 1e-6.
    #[test]
    fn kill_everything_then_resume_from_last_committed_epoch() {
        let steps = 40;
        let dir = tmp_dir("drill_resume");

        let train_args = |remote: &str, extra: &[String]| -> Vec<String> {
            let mut args = strs(&["train", "--parity-lines", "true", "--remote-ps"]);
            args.push(remote.to_string());
            args.extend(shared_flags(steps, 1));
            args.extend(extra.to_vec());
            args
        };

        // --- the run that dies ---
        let (ps_a, addr_a) = spawn_ps("127.0.0.1:0", "0..2", steps, 1, &dir, None);
        let (ps_b, addr_b) = spawn_ps("127.0.0.1:0", "2..4", steps, 1, &dir, None);
        let remote = format!("{addr_a},{addr_b}");
        let mut doomed = Proc::spawn(&train_args(
            &remote,
            &[
                "--checkpoint-dir".to_string(),
                dir.display().to_string(),
                "--checkpoint-every".to_string(),
                "8".to_string(),
            ],
        ));
        doomed
            .wait_for_line("CKPT epoch ", Duration::from_secs(120))
            .unwrap_or_else(|| panic!("no epoch committed:\n{}", doomed.output_snapshot()));
        // SIGKILL the whole deployment: trainer first (no more commits can
        // start), then both shards.
        doomed.kill();
        let (mut ps_a, mut ps_b) = (ps_a, ps_b);
        ps_a.kill();
        ps_b.kill();

        // --- resume from the last globally committed epoch ---
        let epoch: u64 = std::fs::read_to_string(dir.join("LATEST"))
            .expect("LATEST pointer written")
            .trim()
            .parse()
            .expect("LATEST holds a step");
        assert!(epoch >= 8 && epoch < steps as u64, "implausible epoch {epoch}");
        let (ps_a2, addr_a2) = spawn_ps("127.0.0.1:0", "0..2", steps, 1, &dir, Some(epoch));
        let (ps_b2, addr_b2) = spawn_ps("127.0.0.1:0", "2..4", steps, 1, &dir, Some(epoch));
        assert!(
            ps_a2.output_snapshot().contains("from committed epoch step-"),
            "restarted shard did not restore an epoch:\n{}",
            ps_a2.output_snapshot()
        );
        let mut resumed = Proc::spawn(&train_args(
            &format!("{addr_a2},{addr_b2}"),
            &["--resume-from".to_string(), dir.display().to_string()],
        ));
        let status = resumed
            .wait_timeout(Duration::from_secs(300))
            .unwrap_or_else(|| panic!("resumed run hung:\n{}", resumed.output_snapshot()));
        assert!(status.success(), "resumed run failed:\n{}", resumed.output_snapshot());
        let resumed_out = resumed.output_snapshot();
        assert!(
            resumed_out.contains(&format!("resuming from committed checkpoint epoch {epoch}")),
            "{resumed_out}"
        );
        drop(ps_a2);
        drop(ps_b2);

        // --- the unkilled reference deployment (fresh dir, no checkpoints:
        // epochs are pure observation) ---
        let dir_ref = tmp_dir("drill_resume_ref");
        let (ps_a3, addr_a3) = spawn_ps("127.0.0.1:0", "0..2", steps, 1, &dir_ref, None);
        let (ps_b3, addr_b3) = spawn_ps("127.0.0.1:0", "2..4", steps, 1, &dir_ref, None);
        let mut reference = Proc::spawn(&train_args(&format!("{addr_a3},{addr_b3}"), &[]));
        let status = reference
            .wait_timeout(Duration::from_secs(300))
            .unwrap_or_else(|| panic!("reference run hung:\n{}", reference.output_snapshot()));
        assert!(status.success(), "reference failed:\n{}", reference.output_snapshot());
        let reference_out = reference.output_snapshot();
        drop(ps_a3);
        drop(ps_b3);

        // The resumed segment reproduces the reference exactly (well within
        // the 1e-6 acceptance tolerance).
        let got = parse_losses(&resumed_out);
        assert!(got.iter().all(|(s, _)| *s >= epoch), "resumed losses predate the epoch");
        assert_losses_match(&got, &parse_losses(&reference_out), "resume drill");
        let (loss, auc) = parse_parity(&resumed_out);
        let (ref_loss, ref_auc) = parse_parity(&reference_out);
        assert!((loss - ref_loss).abs() <= 1e-6, "final loss {loss} vs {ref_loss}");
        assert!((auc - ref_auc).abs() <= 1e-6, "final AUC {auc} vs {ref_auc}");

        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir_ref).ok();
    }

    /// The tiered-storage variant of the wholesale kill drill: both PS
    /// shards run with a disk-backed cold tier and a hot budget far below
    /// the working set, the committed epoch carries BOTH tiers on disk, and
    /// the restarted cold-backed deployment resumes to ≤1e-6 parity with an
    /// unkilled ALL-HOT reference — row placement stays invisible to the
    /// numerics even across a SIGKILL + epoch restore.
    #[test]
    fn kill_cold_backed_deployment_then_resume_restores_both_tiers() {
        let steps = 40;
        let dir = tmp_dir("drill_cold");
        let cold_dir = dir.join("cold");
        let tiered = vec![
            "--cold-dir".to_string(),
            cold_dir.display().to_string(),
            "--hot-capacity".to_string(),
            "128".to_string(),
        ];

        let train_args = |remote: &str, extra: &[String]| -> Vec<String> {
            let mut args = strs(&["train", "--parity-lines", "true", "--remote-ps"]);
            args.push(remote.to_string());
            args.extend(shared_flags(steps, 1));
            args.extend(extra.to_vec());
            args
        };

        // --- the cold-backed run that dies ---
        let (ps_a, addr_a) =
            spawn_ps_with("127.0.0.1:0", "0..2", steps, 1, &dir, None, &tiered);
        let (ps_b, addr_b) =
            spawn_ps_with("127.0.0.1:0", "2..4", steps, 1, &dir, None, &tiered);
        for ps in [&ps_a, &ps_b] {
            assert!(
                ps.output_snapshot().contains("tiered hot=128/shard"),
                "shard did not report the tiered engine:\n{}",
                ps.output_snapshot()
            );
        }
        let mut doomed = Proc::spawn(&train_args(
            &format!("{addr_a},{addr_b}"),
            &[
                "--checkpoint-dir".to_string(),
                dir.display().to_string(),
                "--checkpoint-every".to_string(),
                "8".to_string(),
            ],
        ));
        doomed
            .wait_for_line("CKPT epoch ", Duration::from_secs(120))
            .unwrap_or_else(|| panic!("no epoch committed:\n{}", doomed.output_snapshot()));
        doomed.kill();
        let (mut ps_a, mut ps_b) = (ps_a, ps_b);
        ps_a.kill();
        ps_b.kill();

        // --- the committed epoch must carry the cold tier for every node ---
        let epoch: u64 = std::fs::read_to_string(dir.join("LATEST"))
            .expect("LATEST pointer written")
            .trim()
            .parse()
            .expect("LATEST holds a step");
        assert!(epoch >= 8 && epoch < steps as u64, "implausible epoch {epoch}");
        for node in 0..4 {
            let cold_file =
                dir.join(format!("step-{epoch}")).join(format!("ps_node_{node}.cold"));
            assert!(
                cold_file.exists(),
                "committed epoch is missing its cold tier: {}",
                cold_file.display()
            );
        }

        // --- restart both shards cold-backed, pinned to the epoch ---
        let (ps_a2, addr_a2) =
            spawn_ps_with("127.0.0.1:0", "0..2", steps, 1, &dir, Some(epoch), &tiered);
        let (ps_b2, addr_b2) =
            spawn_ps_with("127.0.0.1:0", "2..4", steps, 1, &dir, Some(epoch), &tiered);
        for ps in [&ps_a2, &ps_b2] {
            let out = ps.output_snapshot();
            assert!(
                out.contains("from committed epoch step-"),
                "restarted shard did not restore an epoch:\n{out}"
            );
        }
        let mut resumed = Proc::spawn(&train_args(
            &format!("{addr_a2},{addr_b2}"),
            &["--resume-from".to_string(), dir.display().to_string()],
        ));
        let status = resumed
            .wait_timeout(Duration::from_secs(300))
            .unwrap_or_else(|| panic!("resumed run hung:\n{}", resumed.output_snapshot()));
        assert!(status.success(), "resumed run failed:\n{}", resumed.output_snapshot());
        let resumed_out = resumed.output_snapshot();
        drop(ps_a2);
        drop(ps_b2);

        // --- the unkilled ALL-HOT reference (fresh dir, default engine):
        // both the kill and the tiering must be invisible to the numerics ---
        let dir_ref = tmp_dir("drill_cold_ref");
        let (ps_a3, addr_a3) = spawn_ps("127.0.0.1:0", "0..2", steps, 1, &dir_ref, None);
        let (ps_b3, addr_b3) = spawn_ps("127.0.0.1:0", "2..4", steps, 1, &dir_ref, None);
        let mut reference = Proc::spawn(&train_args(&format!("{addr_a3},{addr_b3}"), &[]));
        let status = reference
            .wait_timeout(Duration::from_secs(300))
            .unwrap_or_else(|| panic!("reference run hung:\n{}", reference.output_snapshot()));
        assert!(status.success(), "reference failed:\n{}", reference.output_snapshot());
        let reference_out = reference.output_snapshot();
        drop(ps_a3);
        drop(ps_b3);

        let got = parse_losses(&resumed_out);
        assert!(got.iter().all(|(s, _)| *s >= epoch), "resumed losses predate the epoch");
        assert_losses_match(&got, &parse_losses(&reference_out), "cold-backed resume drill");
        let (loss, auc) = parse_parity(&resumed_out);
        let (ref_loss, ref_auc) = parse_parity(&reference_out);
        assert!((loss - ref_loss).abs() <= 1e-6, "final loss {loss} vs {ref_loss}");
        assert!((auc - ref_auc).abs() <= 1e-6, "final AUC {auc} vs {ref_auc}");

        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir_ref).ok();
    }

    /// Threaded in-process replica of the three-tier drill's config (the
    /// same preset pipeline the children use), for the unkilled baseline —
    /// PR 3/4 proved threads ≡ processes for exactly this setup.
    fn baseline_trainer(steps: usize, nn_workers: usize) -> Trainer {
        let preset = BenchPreset::by_name(PRESET).unwrap();
        let model = preset.model(DENSE);
        let emb_cfg = preset.embedding(&model, CAPACITY.parse().unwrap());
        let rows = preset.embedding(&model, 1).rows_per_group;
        let cluster = ClusterConfig {
            n_nn_workers: nn_workers,
            n_emb_workers: 1,
            net: NetModelConfig::disabled(),
        };
        let train = TrainConfig {
            mode: TrainMode::FullSync,
            batch_size: BATCH.parse().unwrap(),
            lr: 0.05,
            staleness_bound: 4,
            steps,
            eval_every: steps,
            seed: SEED.parse().unwrap(),
            use_pjrt: false,
            compress: false,
        };
        let dataset =
            SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED.parse().unwrap());
        let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
        t.deterministic = true;
        t
    }

    /// THE tentpole acceptance drill: 2 PS shards × 1 embedding worker × 2
    /// NN ranks; one shard is SIGKILLed mid-run and restarted from its
    /// committed epoch; the unified recovery layer (reconnect pool +
    /// put-replay log + re-buffered pushes) carries the run to completion
    /// within 1e-6 of the unkilled baseline.
    #[test]
    fn sigkill_one_ps_shard_three_tier_run_survives_to_parity() {
        let steps = 30;
        let world = 2;
        let dir = tmp_dir("drill_sigkill");

        // Unkilled baseline (threaded — equivalence to the process
        // deployment is the already-proven PR 3/4 property).
        let baseline = baseline_trainer(steps, world).run_rust().unwrap();
        let base_auc = baseline.report.final_auc.unwrap();

        // --- PS tier (checkpoint-enabled) ---
        let (ps_a, addr_a) = spawn_ps("127.0.0.1:0", "0..2", steps, world, &dir, None);
        let (mut ps_b, addr_b) = spawn_ps("127.0.0.1:0", "2..4", steps, world, &dir, None);
        let remote = format!("{addr_a},{addr_b}");

        // --- embedding-worker tier: owns the PS pools, generous retries +
        // the gradient replay log (the exact-recovery machinery) ---
        let mut ew_args = strs(&["serve-embedding-worker", "--addr", "127.0.0.1:0"]);
        ew_args.extend(shared_flags(steps, world));
        ew_args.push("--remote-ps".to_string());
        ew_args.push(remote);
        ew_args.extend(strs(&[
            "--ps-replay", "true", "--ps-retries", "200", "--ps-retry-ms", "100",
        ]));
        let mut ew = Proc::spawn(&ew_args);
        let ew_line = ew
            .wait_for_line("embedding worker listening on ", Duration::from_secs(60))
            .unwrap_or_else(|| panic!("EW never listened:\n{}", ew.output_snapshot()));
        let ew_addr = ew_line
            .split("listening on ")
            .nth(1)
            .and_then(|r| r.split_whitespace().next())
            .expect("EW address")
            .to_string();

        // --- NN tier: 2 train-worker ranks, checkpointing every 5 steps ---
        let worker_args = |rank: usize, rendezvous: &str| -> Vec<String> {
            let mut args = strs(&["train-worker", "--rank"]);
            args.push(rank.to_string());
            args.push("--world".to_string());
            args.push(world.to_string());
            args.push("--rendezvous".to_string());
            args.push(rendezvous.to_string());
            args.extend(strs(&["--ring-timeout-ms", "180000", "--embedding-workers"]));
            args.push(ew_addr.clone());
            args.extend(strs(&["--ew-retries", "20", "--ew-retry-ms", "250"]));
            args.extend(shared_flags(steps, world));
            args.push("--checkpoint-dir".to_string());
            args.push(dir.display().to_string());
            args.extend(strs(&["--checkpoint-every", "5"]));
            args
        };
        let mut w0 = Proc::spawn(&worker_args(0, "127.0.0.1:0"));
        let rdzv_line = w0
            .wait_for_line("rendezvous listening on ", Duration::from_secs(60))
            .unwrap_or_else(|| panic!("rank 0 never printed rendezvous:\n{}", w0.output_snapshot()));
        let rendezvous = rdzv_line
            .split("rendezvous listening on ")
            .nth(1)
            .and_then(|r| r.split_whitespace().next())
            .expect("rendezvous address")
            .to_string();
        let mut w1 = Proc::spawn(&worker_args(1, &rendezvous));

        // Let the first epoch commit, then SIGKILL one shard mid-run.
        w0.wait_for_line("CKPT epoch 5 committed", Duration::from_secs(180))
            .unwrap_or_else(|| panic!("no epoch committed:\n{}", w0.output_snapshot()));
        ps_b.kill();
        // Let some traffic actually fail against the dead shard.
        std::thread::sleep(Duration::from_millis(400));
        // Restart the SAME address from its committed epoch (its own
        // --checkpoint-dir picks the newest committed one).
        let (ps_b2, addr_b2) = spawn_ps(&addr_b, "2..4", steps, world, &dir, None);
        assert_eq!(addr_b2, addr_b, "victim must come back on its own address");
        assert!(
            ps_b2.output_snapshot().contains("from committed epoch step-"),
            "restarted shard did not restore its epoch:\n{}",
            ps_b2.output_snapshot()
        );

        // The run survives and completes...
        let s0 = w0
            .wait_timeout(Duration::from_secs(300))
            .unwrap_or_else(|| panic!("rank 0 hung:\n{}", w0.output_snapshot()));
        let s1 = w1
            .wait_timeout(Duration::from_secs(300))
            .unwrap_or_else(|| panic!("rank 1 hung:\n{}", w1.output_snapshot()));
        assert!(s0.success(), "rank 0 failed:\n{}", w0.output_snapshot());
        assert!(s1.success(), "rank 1 failed:\n{}", w1.output_snapshot());

        // ...to parity with the unkilled baseline (≤ 1e-6 on every loss +
        // the final loss/AUC — the ISSUE-5 acceptance bound).
        let out0 = w0.output_snapshot();
        let got = parse_losses(&out0);
        let want: Vec<(u64, f32)> = baseline.tracker.losses.clone();
        assert_eq!(got.len(), want.len(), "loss curve lengths differ");
        assert_losses_match(&got, &want, "sigkill drill");
        let (loss, auc) = parse_parity(&out0);
        let base_loss = baseline.report.final_loss;
        assert!(
            (loss - base_loss).abs() <= 1e-6,
            "final loss {loss} vs baseline {base_loss}"
        );
        assert!((auc - base_auc).abs() <= 1e-6, "final AUC {auc} vs baseline {base_auc}");

        drop(ps_a);
        drop(ps_b2);
        drop(ew);
        std::fs::remove_dir_all(&dir).ok();
    }
}
