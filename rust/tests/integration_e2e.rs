//! End-to-end integration: full stack (loader → emb workers → dense engine →
//! AllReduce → PS) across engines and modes.

use persia::config::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::hybrid::{PjrtEngineFactory, Trainer};
use persia::runtime::ArtifactManifest;

fn tiny_model() -> ModelConfig {
    ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 4,
        emb_dim_per_group: 8,
        nid_dim: 8,
        hidden: vec![32, 16],
        ids_per_group: 4,
        pooling: Pooling::Sum,
    }
}

fn trainer(mode: TrainMode, steps: usize, batch: usize, k: usize, seed: u64) -> Trainer {
    let model = tiny_model();
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 2000,
        shard_capacity: 8192,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster =
        ClusterConfig { n_nn_workers: k, n_emb_workers: 2, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode,
        batch_size: batch,
        lr: 0.1,
        staleness_bound: 4,
        steps,
        eval_every: 0,
        seed,
        use_pjrt: false,
        compress: true,
    };
    let dataset = SyntheticDataset::new(&model, 2000, 1.05, seed);
    Trainer::new(model, emb_cfg, cluster, train, dataset)
}

fn artifacts_available() -> bool {
    ArtifactManifest::default_dir().join("manifest.txt").exists()
}

#[test]
fn pjrt_hybrid_training_learns() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut t = trainer(TrainMode::Hybrid, 250, 32, 2, 11);
    t.train.use_pjrt = true;
    t.train.eval_every = 125;
    t.eval_rows = 1536;
    let factory =
        PjrtEngineFactory { artifacts_dir: ArtifactManifest::default_dir(), preset: "tiny".into() };
    let out = t.run(&factory).unwrap();
    let early: f32 = out.tracker.losses[..20].iter().map(|(_, l)| l).sum::<f32>() / 20.0;
    let late = out.tracker.recent_loss(20).unwrap();
    assert!(late < early, "PJRT loss did not drop: {early} -> {late}");
    let auc = out.report.final_auc.unwrap();
    assert!(auc > 0.58, "PJRT AUC too low: {auc}");
}

#[test]
fn pjrt_and_rust_training_curves_are_close() {
    if !artifacts_available() {
        return;
    }
    // Same seed, same data => the two engines should produce very similar
    // loss trajectories (identical up to f32 reduction order).
    let mut tp = trainer(TrainMode::FullSync, 60, 32, 1, 5);
    tp.train.use_pjrt = true;
    let factory =
        PjrtEngineFactory { artifacts_dir: ArtifactManifest::default_dir(), preset: "tiny".into() };
    let out_p = tp.run(&factory).unwrap();

    let tr = trainer(TrainMode::FullSync, 60, 32, 1, 5);
    let out_r = tr.run_rust().unwrap();

    // Engines use different weight inits (factory-internal RNG), so compare
    // trajectory shape, not values: both monotone-ish decreasing.
    let drop_p = out_p.tracker.losses[0].1 - out_p.tracker.recent_loss(5).unwrap();
    let drop_r = out_r.tracker.losses[0].1 - out_r.tracker.recent_loss(5).unwrap();
    assert!(drop_p > 0.0 && drop_r > 0.0, "{drop_p} {drop_r}");
}

#[test]
fn hybrid_matches_sync_auc_and_beats_async() {
    // The paper's central statistical claim (Fig. 7 / Table 2): hybrid ≈
    // sync on AUC; fully async (drifting replicas, unbounded staleness)
    // loses measurable AUC. Multi-seed averaged to de-noise.
    let steps = 400;
    let mut aucs = std::collections::HashMap::new();
    for mode in [TrainMode::FullSync, TrainMode::Hybrid, TrainMode::FullAsync] {
        let mut total = 0.0;
        let seeds = [3u64, 17, 29];
        for &seed in &seeds {
            let mut t = trainer(mode, steps, 64, 4, seed);
            t.train.eval_every = steps;
            t.eval_rows = 2048;
            // Aggressive embedding staleness for async.
            if mode == TrainMode::FullAsync {
                t.train.staleness_bound = 16;
            }
            let out = t.run_rust().unwrap();
            total += out.report.final_auc.unwrap();
        }
        aucs.insert(mode.name(), total / seeds.len() as f64);
    }
    let sync = aucs["sync"];
    let hybrid = aucs["hybrid"];
    let asynch = aucs["async"];
    println!("sync={sync:.4} hybrid={hybrid:.4} async={asynch:.4}");
    assert!(sync > 0.60, "sync under-trained: {sync}");
    assert!((sync - hybrid).abs() < 0.02, "hybrid-vs-sync gap too large: {sync} vs {hybrid}");
    assert!(hybrid >= asynch - 0.005, "async unexpectedly beat hybrid: {hybrid} vs {asynch}");
}

#[test]
fn throughput_ordering_under_netsim() {
    // Fig. 9-right shape: sim-time throughput hybrid > sync.
    let run = |mode| {
        let mut t = trainer(mode, 60, 64, 4, 7);
        t.cluster.net = NetModelConfig::paper_like();
        t.run_rust().unwrap().report.samples_per_sec
    };
    let sync = run(TrainMode::FullSync);
    let hybrid = run(TrainMode::Hybrid);
    let asynch = run(TrainMode::FullAsync);
    println!("thpt sync={sync:.0} hybrid={hybrid:.0} async={asynch:.0}");
    assert!(hybrid > sync, "hybrid {hybrid} !> sync {sync}");
    assert!(asynch >= hybrid * 0.8, "async {asynch} unexpectedly slow vs {hybrid}");
}

#[test]
fn compression_does_not_hurt_convergence() {
    let run = |compress| {
        let mut t = trainer(TrainMode::Hybrid, 250, 64, 2, 13);
        t.train.compress = compress;
        t.train.eval_every = 250;
        t.run_rust().unwrap().report.final_auc.unwrap()
    };
    let with = run(true);
    let without = run(false);
    println!("auc with compression={with:.4} without={without:.4}");
    assert!((with - without).abs() < 0.015, "compression AUC gap: {with} vs {without}");
}
