//! Property tests for the live-resharding planner and its durable
//! artifacts (`service/reshard.rs`).
//!
//! Two families, mirroring `property_recovery.rs`:
//!
//! * **Planner laws** over random deployments + traffic: any plan the
//!   planner emits must keep the routing table TOTAL (every node owned by
//!   exactly one shard), move ONLY the planned range (minimal movement),
//!   bump the epoch by exactly one, and strictly reduce the measured
//!   imbalance — and applying the plan must agree with direct lookup for
//!   every node.
//! * **Codec totality**: arbitrary, truncated, or bit-flipped
//!   `RoutingTable`/`MigrationPlan` bytes must never panic the parser and
//!   never yield a structurally inconsistent value. These bytes cross the
//!   wire at a PREPARE barrier and live in the persisted `ROUTING` file —
//!   a panic here takes down a shard mid-migration; silently accepting
//!   garbage re-routes live traffic to the wrong process.

use persia::service::reshard::{
    apply, plan_rebalance, process_imbalance, MigrationPlan, RoutingTable,
};
use persia::util::quickcheck::forall;
use persia::util::Rng;

/// A random deployment derived deterministically from `seed`: 2..=5 shard
/// processes of which the first 1..=s serve a contiguous slice of the node
/// space (the rest are idle spares), plus random per-node traffic.
fn build_case(seed: u64) -> (RoutingTable, Vec<u64>) {
    let mut rng = Rng::new(seed ^ 0x5E5A_4D0D);
    let s = 2 + rng.below(4) as usize;
    let k = 1 + rng.below(s as u64) as usize;
    let n_nodes = k + rng.below(12) as usize;
    // Distribute the surplus nodes over the serving shards (each keeps >= 1).
    let mut sizes = vec![1usize; k];
    for _ in 0..(n_nodes - k) {
        let i = rng.below(k as u64) as usize;
        sizes[i] += 1;
    }
    let mut ranges = Vec::with_capacity(s);
    let mut start = 0usize;
    for i in 0..s {
        if i < k {
            let end = start + sizes[i];
            ranges.push(start..end);
            start = end;
        } else {
            ranges.push(0..0);
        }
    }
    let addrs: Vec<String> = (0..s).map(|i| format!("127.0.0.1:77{i:02}")).collect();
    let table = RoutingTable::initial(n_nodes, &ranges, &addrs).expect("generated partition");
    let traffic: Vec<u64> = (0..n_nodes).map(|_| rng.below(1000)).collect();
    (table, traffic)
}

#[test]
fn any_emitted_plan_is_total_minimal_and_strictly_improving() {
    forall(
        31,
        400,
        |rng: &mut Rng| (rng.next_u64(), 101 + rng.below(100)),
        |&(seed, threshold_bps)| {
            let (table, traffic) = build_case(seed);
            let threshold = threshold_bps as f64 / 100.0; // 1.01..=2.00
            let Some(plan) = plan_rebalance(&table, &traffic, threshold) else {
                // Refusing is always allowed; the planner's side of the
                // bargain only starts once it emits a plan.
                return true;
            };
            // A plan may only ever be emitted at or above the threshold.
            if process_imbalance(&table, &traffic) < threshold {
                return false;
            }
            let Ok(next) = apply(&table, &plan) else {
                return false; // the planner emitted a plan its own table rejects
            };
            // Totality: every node owned by exactly one shard, indices valid.
            let total = next.validate().is_ok() && next.owner.len() == table.n_nodes;
            // Epoch advances by exactly one.
            let epoch_ok = next.epoch == table.epoch + 1;
            // Epoch N+1 ∘ plan = direct lookup, and ONLY the planned range
            // moved (minimal movement).
            let minimal = (0..table.n_nodes).all(|n| {
                if plan.nodes.contains(&n) {
                    table.owner[n] == plan.source as u32 && next.owner[n] == plan.dest as u32
                } else {
                    next.owner[n] == table.owner[n]
                }
            });
            // The move must strictly reduce the measured imbalance.
            let improved =
                process_imbalance(&next, &traffic) < process_imbalance(&table, &traffic);
            // Ownership stays contiguous for every shard (checkpoint file
            // naming and MIGRATE_OUT streaming both rely on it).
            let contiguous = (0..next.addrs.len()).all(|s| next.owned_range(s).is_ok());
            total && epoch_ok && minimal && improved && contiguous
        },
    )
}

#[test]
fn planner_never_panics_on_degenerate_traffic() {
    // Short traffic slices, all-zero traffic, and absurd thresholds must
    // all refuse cleanly (the coordinator feeds the planner whatever the
    // fleet's STATS merge produced).
    forall(
        37,
        200,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let (table, traffic) = build_case(seed);
            let short = &traffic[..traffic.len() / 2];
            let a = plan_rebalance(&table, short, 1.1).is_none();
            let b = plan_rebalance(&table, &vec![0; table.n_nodes], 1.01).is_none();
            let c = plan_rebalance(&table, &traffic, 0.0).is_none();
            let d = plan_rebalance(&table, &traffic, f64::INFINITY).is_none();
            a && b && c && d
        },
    )
}

/// Parsing must be total: `Ok` with a structurally consistent table, or a
/// clean `Err` — never a panic, never an inconsistent value.
fn table_parse_is_total(bytes: &[u8]) -> bool {
    match RoutingTable::from_bytes(bytes) {
        Err(_) => true,
        Ok(t) => t.validate().is_ok() && t.owner.len() == t.n_nodes,
    }
}

fn plan_parse_is_total(bytes: &[u8]) -> bool {
    match MigrationPlan::from_bytes(bytes) {
        Err(_) => true,
        Ok(p) => p.validate().is_ok(),
    }
}

#[test]
fn arbitrary_bytes_never_panic_either_codec() {
    forall(
        41,
        400,
        |rng: &mut Rng| {
            let n = rng.below(300) as usize;
            let mut bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            // Half the time splice in a valid magic so the parse walks past
            // the header check into the CRC and body validation.
            if rng.below(2) == 0 && bytes.len() >= 8 {
                let magic: &[u8; 8] =
                    if rng.below(2) == 0 { b"PRRT0001" } else { b"PRMP0001" };
                bytes[..8].copy_from_slice(magic);
            }
            bytes
        },
        |bytes| table_parse_is_total(bytes) && plan_parse_is_total(bytes),
    )
}

#[test]
fn truncated_or_bitflipped_tables_are_rejected_not_panicked() {
    let valid = RoutingTable::initial(
        6,
        &[0..4, 4..6, 0..0],
        &["127.0.0.1:7701".into(), "127.0.0.1:7702".into(), "127.0.0.1:7703".into()],
    )
    .unwrap()
    .to_bytes();
    forall(
        43,
        300,
        |rng: &mut Rng| {
            let mut bytes = valid.clone();
            if rng.below(2) == 0 {
                bytes.truncate(rng.below(bytes.len() as u64 + 1) as usize);
            } else {
                let i = rng.below(bytes.len() as u64) as usize;
                bytes[i] ^= 1 << rng.below(8);
            }
            bytes
        },
        |bytes| {
            if *bytes == valid {
                // Truncation to full length is the identity escape.
                RoutingTable::from_bytes(bytes).is_ok()
            } else {
                table_parse_is_total(bytes) && RoutingTable::from_bytes(bytes).is_err()
            }
        },
    )
}

#[test]
fn truncated_or_bitflipped_plans_are_rejected_not_panicked() {
    let valid =
        MigrationPlan { from_epoch: 7, source: 0, dest: 2, nodes: 2..4 }.to_bytes();
    forall(
        47,
        300,
        |rng: &mut Rng| {
            let mut bytes = valid.clone();
            if rng.below(2) == 0 {
                bytes.truncate(rng.below(bytes.len() as u64 + 1) as usize);
            } else {
                let i = rng.below(bytes.len() as u64) as usize;
                bytes[i] ^= 1 << rng.below(8);
            }
            bytes
        },
        |bytes| {
            if *bytes == valid {
                MigrationPlan::from_bytes(bytes).is_ok()
            } else {
                plan_parse_is_total(bytes) && MigrationPlan::from_bytes(bytes).is_err()
            }
        },
    )
}

#[test]
fn table_roundtrip_is_exact_at_any_epoch() {
    forall(
        53,
        200,
        |rng: &mut Rng| (rng.next_u64(), rng.below(1 << 30)),
        |&(seed, epoch)| {
            let (mut table, _) = build_case(seed);
            table.epoch = epoch; // epochs beyond 0 must survive unchanged
            RoutingTable::from_bytes(&table.to_bytes())
                .map(|back| back == table)
                .unwrap_or(false)
        },
    )
}
