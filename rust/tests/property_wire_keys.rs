//! Property tests (via `util::quickcheck`) for the two protocol-critical
//! invariants the TCP service mode rests on:
//!
//! * `comm::wire` encode/decode is a lossless round-trip for arbitrary
//!   multi-section messages, and rejects (never panics on) truncation;
//! * `embedding::ps::{pack_key, unpack_key}` are mutually inverse and the
//!   id component always stays inside the 48-bit key space.

use persia::comm::wire::{WireReader, WireWriter};
use persia::embedding::ps::{pack_key, unpack_key};
use persia::util::quickcheck::forall;
use persia::util::Rng;

fn gen_f32s(rng: &mut Rng, max_len: u64) -> Vec<f32> {
    (0..rng.below(max_len + 1)).map(|_| (rng.f32() * 2.0 - 1.0) * 1e6).collect()
}

fn gen_u64s(rng: &mut Rng, max_len: u64) -> Vec<u64> {
    (0..rng.below(max_len + 1)).map(|_| rng.next_u64()).collect()
}

fn gen_u16s(rng: &mut Rng, max_len: u64) -> Vec<u16> {
    (0..rng.below(max_len + 1)).map(|_| rng.below(1 << 16) as u16).collect()
}

#[test]
fn property_mixed_section_roundtrip_is_lossless() {
    forall(
        101,
        300,
        |rng: &mut Rng| (gen_f32s(rng, 64), gen_u64s(rng, 64), gen_u16s(rng, 64)),
        |(fs, us, hs)| {
            let kind = (fs.len() + us.len() + hs.len()) as u32;
            let mut w = WireWriter::new(kind);
            w.put_f32(fs).put_u64(us).put_u16(hs).put_u8(b"tail");
            let msg = w.finish();
            let r = match WireReader::parse(&msg) {
                Ok(r) => r,
                Err(_) => return false,
            };
            r.kind() == kind
                && r.n_sections() == 4
                && r.f32(0).map(|v| v == *fs).unwrap_or(false)
                && r.u64(1).map(|v| v == *us).unwrap_or(false)
                && r.u16(2).map(|v| v == *hs).unwrap_or(false)
                && r.u8(3).map(|v| v == b"tail").unwrap_or(false)
        },
    );
}

#[test]
fn property_f16_sections_roundtrip_bit_patterns() {
    forall(
        103,
        300,
        |rng: &mut Rng| gen_u16s(rng, 128),
        |hs| {
            let mut w = WireWriter::new(9);
            w.put_f16(hs);
            let msg = w.finish();
            WireReader::parse(&msg)
                .and_then(|r| r.f16(0))
                .map(|v| v == *hs)
                .unwrap_or(false)
        },
    );
}

#[test]
fn property_truncated_messages_error_never_panic() {
    forall(
        107,
        500,
        |rng: &mut Rng| (gen_f32s(rng, 32), rng.below(1 << 16)),
        |(fs, cut_seed)| {
            let mut w = WireWriter::new(1);
            w.put_f32(fs).put_u64(&[7]);
            let msg = w.finish();
            let cut = (*cut_seed as usize) % msg.len().max(1);
            // Any strict prefix must parse to Err or to sections that fail
            // typed reads — never panic, never read out of bounds.
            match WireReader::parse(&msg[..cut]) {
                Err(_) => true,
                Ok(r) => r.f32(0).is_err() || r.u64(1).is_err() || cut == msg.len(),
            }
        },
    );
}

#[test]
fn property_pack_unpack_inverse_within_bounds() {
    forall(
        109,
        1000,
        |rng: &mut Rng| (rng.below(1 << 16), rng.below(1 << 48)),
        |&(group, id)| {
            let key = pack_key(group as u32, id);
            unpack_key(key) == (group as u32, id)
        },
    );
}

#[test]
fn property_unpack_id_always_fits_48_bits_and_repacks() {
    // pack ∘ unpack is the identity on the full u64 key space, and the
    // unpacked id can never escape the 48-bit row space.
    forall(
        113,
        1000,
        |rng: &mut Rng| rng.next_u64(),
        |&key| {
            let (group, id) = unpack_key(key);
            id < (1u64 << 48) && pack_key(group, id) == key
        },
    );
}

#[test]
fn property_distinct_keys_never_collide_across_groups() {
    forall(
        127,
        1000,
        |rng: &mut Rng| {
            (
                (rng.below(1 << 16), rng.below(1 << 48)),
                (rng.below(1 << 16), rng.below(1 << 48)),
            )
        },
        |&((g1, id1), (g2, id2))| {
            let same_input = (g1, id1) == (g2, id2);
            let same_key = pack_key(g1 as u32, id1) == pack_key(g2 as u32, id2);
            same_input == same_key
        },
    );
}
