//! Cross-process FullAsync gossip: the ISSUE-6 acceptance drills.
//!
//! * Parity: two `Trainer::run_rank` threads joined by a loopback TCP ring
//!   (whose `replica_average` is the real peer-to-peer gossip mesh, not a
//!   ring collective) reproduce the threaded `Trainer::run` FullAsync
//!   numbers within 1e-6 when deterministic ordering is on.
//! * Liveness: a peer that stalls 100 ms every round must not slow the
//!   other ranks' best-effort `replica_average` at all — the fire-and-
//!   forget path never waits on any peer.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use persia::allreduce::RingRendezvous;
use persia::comm::NetSim;
use persia::config::{
    BenchPreset, ClusterConfig, NetModelConfig, RingConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::embedding::EmbeddingPs;
use persia::hybrid::{DenseComm, Trainer};

const PRESET: &str = "taobao";
const DENSE: &str = "tiny";
const CAPACITY: usize = 2048;
const SEED: u64 = 42;
const BATCH: usize = 32;
const GOSSIP_PERIOD: u64 = 8;

/// A deterministic FullAsync trainer built through the preset pipeline, so
/// the threaded baseline and the TCP-ring ranks share every config bit.
fn preset_trainer(steps: usize, world: usize) -> Trainer {
    let preset = BenchPreset::by_name(PRESET).unwrap();
    let model = preset.model(DENSE);
    let emb_cfg = preset.embedding(&model, CAPACITY);
    let rows = preset.embedding(&model, 1).rows_per_group;
    let cluster = ClusterConfig {
        n_nn_workers: world,
        n_emb_workers: 2,
        net: NetModelConfig::disabled(),
    };
    let train = TrainConfig {
        mode: TrainMode::FullAsync,
        batch_size: BATCH,
        lr: 0.05,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: SEED,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    t.gossip_period = GOSSIP_PERIOD;
    t
}

fn ring_cfg(rank: usize, world: usize, rendezvous: &str) -> RingConfig {
    RingConfig {
        rendezvous: rendezvous.to_string(),
        rank,
        world,
        bind_host: "127.0.0.1".to_string(),
        timeout_ms: 30_000,
        compress: false,
    }
}

/// Deterministic FullAsync across a real loopback TCP ring + gossip mesh
/// must reproduce the threaded shared-slot run: same token order, same
/// accumulation order, so losses, AUC, and rank 0's final dense params
/// agree within 1e-6 (the gossip average is ordered under the ring token).
#[test]
fn tcp_gossip_async_run_rank_matches_threaded_run() {
    let steps = 40;
    let baseline = preset_trainer(steps, 2).run_rust().unwrap();

    let template = preset_trainer(steps, 2);
    let shared_ps = Arc::new(EmbeddingPs::new(
        &template.emb_cfg,
        template.model.emb_dim_per_group,
        template.train.seed,
    ));
    let rz0 = RingRendezvous::bind(&ring_cfg(0, 2, "127.0.0.1:0")).unwrap();
    let rendezvous = rz0.rendezvous_addr().unwrap().to_string();

    let spawn_rank = |rank: usize, rz: Option<RingRendezvous>, rendezvous: String| {
        let shared_ps = shared_ps.clone();
        std::thread::spawn(move || {
            let mut t = preset_trainer(steps, 2);
            t.ps_backend = Some(shared_ps);
            let fp = t.config_fingerprint();
            let factory = t.rust_engine_factory();
            t.run_rank(&factory, move |net| {
                let rz = match rz {
                    Some(rz) => rz,
                    None => RingRendezvous::bind(&ring_cfg(rank, 2, &rendezvous))?,
                };
                Ok(Box::new(rz.connect(fp, net)?) as Box<dyn DenseComm>)
            })
            .unwrap()
        })
    };
    let h0 = spawn_rank(0, Some(rz0), String::new());
    let h1 = spawn_rank(1, None, rendezvous);
    let out0 = h0.join().unwrap();
    let _out1 = h1.join().unwrap();

    assert_eq!(baseline.tracker.losses.len(), out0.tracker.losses.len());
    for ((sa, la), (sb, lb)) in baseline.tracker.losses.iter().zip(&out0.tracker.losses) {
        assert_eq!(sa, sb);
        assert!((la - lb).abs() <= 1e-6, "step {sa}: loss {la} (threads) vs {lb} (gossip)");
    }
    let auc_a = baseline.report.final_auc.unwrap();
    let auc_b = out0.report.final_auc.unwrap();
    assert!((auc_a - auc_b).abs() <= 1e-6, "AUC {auc_a} (threads) vs {auc_b} (gossip)");
    assert_eq!(baseline.final_params.len(), out0.final_params.len());
    for (a, b) in baseline.final_params.iter().zip(&out0.final_params) {
        assert!((a - b).abs() <= 1e-6, "final params diverged: {a} vs {b}");
    }
    // The run meaningfully trained.
    let early: f32 =
        baseline.tracker.losses[..5].iter().map(|(_, l)| l).sum::<f32>() / 5.0;
    assert!(baseline.tracker.recent_loss(5).unwrap() < early, "did not learn");
}

/// The barrier-removal criterion: with one rank stalling 100 ms per round,
/// the other ranks' best-effort `replica_average` must not degrade — 20
/// rounds stay far under one stall's worth of waiting (the PR-3 ring
/// AllReduce would cost >= 100 ms per round here).
#[test]
fn stalled_peer_does_not_slow_best_effort_gossip() {
    const WORLD: usize = 3;
    const ROUNDS: usize = 20;
    const FP: u64 = 0xFEED;
    let rz0 = RingRendezvous::bind(&ring_cfg(0, WORLD, "127.0.0.1:0")).unwrap();
    let rendezvous = rz0.rendezvous_addr().unwrap().to_string();

    let elapsed: Arc<Mutex<Vec<(usize, Duration)>>> = Arc::new(Mutex::new(Vec::new()));
    let spawn_rank = |rank: usize, rz: Option<RingRendezvous>| {
        let rendezvous = rendezvous.clone();
        let elapsed = elapsed.clone();
        std::thread::spawn(move || {
            let rz = match rz {
                Some(rz) => rz,
                None => RingRendezvous::bind(&ring_cfg(rank, WORLD, &rendezvous)).unwrap(),
            };
            let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
            let mut comm = rz.connect(FP, net).unwrap();
            let mut params = vec![rank as f32; 64];
            for _ in 0..ROUNDS {
                if rank == WORLD - 1 {
                    // The stalled peer: sleep, then post like everyone else.
                    std::thread::sleep(Duration::from_millis(100));
                    DenseComm::replica_average(&mut comm, &mut params).unwrap();
                } else {
                    let t0 = Instant::now();
                    DenseComm::replica_average(&mut comm, &mut params).unwrap();
                    elapsed.lock().unwrap().push((rank, t0.elapsed()));
                    std::thread::sleep(Duration::from_millis(5));
                }
                for p in &params {
                    assert!(p.is_finite(), "gossip corrupted the replica");
                }
            }
        })
    };
    let mut handles = vec![spawn_rank(0, Some(rz0))];
    handles.extend((1..WORLD).map(|r| spawn_rank(r, None)));
    for h in handles {
        h.join().unwrap();
    }

    let samples = elapsed.lock().unwrap();
    for rank in 0..WORLD - 1 {
        let mine: Vec<Duration> =
            samples.iter().filter(|(r, _)| *r == rank).map(|(_, d)| *d).collect();
        assert_eq!(mine.len(), ROUNDS);
        let total: Duration = mine.iter().sum();
        // 20 fire-and-forget averages against a peer stalling 100 ms/round:
        // a barrier would cost >= 2 s; the gossip path must stay well under
        // a tenth of that in total.
        assert!(
            total < Duration::from_millis(200),
            "rank {rank}: {ROUNDS} gossip rounds took {total:?} — blocked on the stalled peer?"
        );
    }
}
