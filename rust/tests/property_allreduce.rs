//! Property tests for the dense-gradient AllReduce stack (§4.2.3):
//! ring AllReduce (threaded and TCP) vs the central-PS reduce vs a serial
//! sum, plus `FlatBuckets` flatten/unflatten roundtrips.
//!
//! Float addition is commutative but not associative, so "ring == serial"
//! splits into two exact statements:
//! * On inputs whose sums are exactly representable (small dyadic
//!   rationals), EVERY reduction order gives the same bits — ring, central
//!   and serial must agree to 0 ULP.
//! * On arbitrary floats, the ring's deterministic reduction order is
//!   replayed by `ring::reference_sum`; every ring member (any rank, thread
//!   or TCP transport) must match it to 0 ULP, and central == serial to
//!   0 ULP (both accumulate in rank order).

use std::sync::Arc;

use persia::allreduce::ring::{chunk_range, reference_mean, reference_sum};
use persia::allreduce::{central_reduce, FlatBuckets, RingGroup};
use persia::comm::NetSim;
use persia::config::NetModelConfig;
use persia::tensor::Tensor;
use persia::util::quickcheck::forall;
use persia::util::Rng;

/// Run the threaded ring over `inputs`; returns each rank's result (mean).
fn ring_mean_outputs(inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let k = inputs.len();
    let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
    let members = RingGroup::new(k, net);
    let handles: Vec<_> = members
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(m, mut buf)| {
            std::thread::spawn(move || {
                m.all_reduce_mean(&mut buf);
                buf
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Serial sum in rank order 0..k (the same association `central_reduce`
/// uses), then the same `* (1/k)` scaling every implementation applies.
fn serial_mean(inputs: &[Vec<f32>]) -> Vec<f32> {
    let n = inputs[0].len();
    let mut out = vec![0.0f32; n];
    for input in inputs {
        for (o, &x) in out.iter_mut().zip(input) {
            *o += x;
        }
    }
    let inv = 1.0 / inputs.len() as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
    out
}

/// Inputs whose elements are dyadic rationals small enough that any sum of
/// up to 8 of them is exactly representable in f32 — every reduction order
/// then yields identical bits.
fn gen_exact_inputs(rng: &mut Rng) -> (usize, Vec<Vec<f32>>) {
    let k = rng.range(1, 9) as usize; // worker counts 1..=8
    let n = rng.range(1, 120) as usize; // arbitrary tensor sizes, incl. n < k
    let inputs = (0..k)
        .map(|_| {
            (0..n)
                .map(|_| (rng.range(0, 2049) as f32 - 1024.0) / 32.0)
                .collect::<Vec<f32>>()
        })
        .collect();
    (k, inputs)
}

/// The quickcheck shrinker mutates structure freely; reject degenerate or
/// ragged shrink candidates instead of panicking inside the property.
fn well_formed(inputs: &[Vec<f32>]) -> bool {
    !inputs.is_empty()
        && !inputs[0].is_empty()
        && inputs.iter().all(|v| v.len() == inputs[0].len())
}

#[test]
fn property_ring_central_serial_identical_on_exact_inputs() {
    forall(
        0xA11,
        60,
        |rng: &mut Rng| gen_exact_inputs(rng).1,
        |inputs| {
            if !well_formed(inputs) {
                return false;
            }
            let serial = serial_mean(inputs);
            let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
            let (central, _) = central_reduce(inputs, &net);
            let ring = ring_mean_outputs(inputs);
            let reference = reference_mean(inputs);
            central == serial
                && reference == serial
                && ring.iter().all(|out| *out == serial)
        },
    );
}

#[test]
fn property_ring_matches_reference_replay_on_arbitrary_floats() {
    forall(
        0xB22,
        60,
        |rng: &mut Rng| {
            let (_, mut inputs) = gen_exact_inputs(rng);
            for input in inputs.iter_mut() {
                for x in input.iter_mut() {
                    *x = rng.normal() * 10.0f32.powi(rng.range(0, 6) as i32 - 3);
                }
            }
            inputs
        },
        |inputs| {
            if !well_formed(inputs) {
                return false;
            }
            // Every rank's ring output replays the documented deterministic
            // reduction order bit-for-bit...
            let reference = reference_mean(inputs);
            let ring = ring_mean_outputs(inputs);
            if !ring.iter().all(|out| *out == reference) {
                return false;
            }
            // ...and central == serial exactly (identical rank-order sums).
            let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
            let (central, _) = central_reduce(inputs, &net);
            if central != serial_mean(inputs) {
                return false;
            }
            // Ring vs serial: different associativity. Bound the gap by the
            // total input magnitude per element (robust to cancellation).
            let n = inputs[0].len();
            (0..n).all(|i| {
                let mag: f32 = inputs.iter().map(|v| v[i].abs()).sum();
                (central[i] - reference[i]).abs() <= mag * 1e-5 + 1e-30
            })
        },
    );
}

#[test]
fn property_reference_sum_agrees_with_chunkwise_definition() {
    // reference_sum's chunk c accumulates ranks c, c+1, ... left-associated;
    // recompute it directly from chunk_range to pin the contract.
    forall(
        0xC33,
        80,
        |rng: &mut Rng| gen_exact_inputs(rng),
        |(k, inputs)| {
            if !well_formed(inputs) || *k != inputs.len() {
                return false;
            }
            let n = inputs[0].len();
            let got = reference_sum(inputs);
            for c in 0..*k {
                let r = chunk_range(n, *k, c);
                let mut want = inputs[c % *k][r.clone()].to_vec();
                for hop in 1..*k {
                    let j = (c + hop) % *k;
                    for (a, &b) in want.iter_mut().zip(&inputs[j][r.clone()]) {
                        *a = b + *a;
                    }
                }
                if got[r.clone()] != want[..] {
                    return false;
                }
            }
            true
        },
    );
}

// ---------------------------------------------------------------------------
// FlatBuckets: flatten/unflatten on arbitrary parameter layouts.
// ---------------------------------------------------------------------------

fn gen_shapes(rng: &mut Rng) -> Vec<Vec<usize>> {
    let n_tensors = rng.range(1, 7) as usize;
    (0..n_tensors)
        .map(|_| {
            let dims = rng.range(1, 4) as usize;
            (0..dims).map(|_| rng.range(1, 7) as usize).collect()
        })
        .collect()
}

fn tensors_for(shapes: &[Vec<usize>], seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    shapes
        .iter()
        .map(|s| Tensor::from_vec(s, rng.normal_vec(s.iter().product())))
        .collect()
}

#[test]
fn property_flatbuckets_roundtrip_arbitrary_layouts_and_bucket_sizes() {
    forall(
        0xD44,
        100,
        |rng: &mut Rng| (gen_shapes(rng), rng.range(1, 40) as usize, rng.next_u64()),
        |(shapes, bucket_elems, seed)| {
            // Reject degenerate shrink candidates (empty shapes, zero dims,
            // zero bucket size) rather than panicking mid-shrink.
            if shapes.is_empty()
                || *bucket_elems == 0
                || shapes.iter().any(|s| s.is_empty() || s.iter().any(|&d| d == 0))
            {
                return false;
            }
            let ts = tensors_for(shapes, *seed);
            let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
            let fb = FlatBuckets::flatten(&ts, *bucket_elems);
            // Flat data is the concatenation in declaration order.
            let want: Vec<f32> = ts.iter().flat_map(|t| t.data().to_vec()).collect();
            if fb.flat() != want.as_slice() || fb.total_elems() != total {
                return false;
            }
            // Bucket count is the ceiling division.
            if fb.n_buckets() != (total + *bucket_elems - 1) / *bucket_elems {
                return false;
            }
            // Roundtrips: fresh allocation and into existing storage.
            if fb.unflatten(shapes) != ts {
                return false;
            }
            let mut out: Vec<Tensor> =
                shapes.iter().map(|s| Tensor::zeros(s)).collect();
            fb.unflatten_into(&mut out);
            out == ts
        },
    );
}

#[test]
fn property_flat_allreduce_equals_per_tensor_reduce_on_exact_inputs() {
    // Reducing the flattened concatenation then unflattening must equal
    // reducing each tensor separately — on exactly-summable inputs, to the
    // bit, regardless of how chunk boundaries fall across tensors.
    forall(
        0xE55,
        40,
        |rng: &mut Rng| {
            let k = rng.range(2, 6) as usize;
            let shapes = gen_shapes(rng);
            let per_worker: Vec<Vec<Vec<f32>>> = (0..k)
                .map(|_| {
                    shapes
                        .iter()
                        .map(|s| {
                            (0..s.iter().product::<usize>())
                                .map(|_| (rng.range(0, 2049) as f32 - 1024.0) / 32.0)
                                .collect()
                        })
                        .collect()
                })
                .collect();
            (shapes, per_worker)
        },
        |(shapes, per_worker)| {
            let k = per_worker.len();
            // Reject degenerate/ragged shrink candidates.
            if k == 0
                || shapes.is_empty()
                || shapes.iter().any(|s| s.is_empty() || s.iter().any(|&d| d == 0))
                || per_worker.iter().any(|ts| {
                    ts.len() != shapes.len()
                        || ts.iter().zip(shapes.iter()).any(|(t, s)| {
                            t.len() != s.iter().product::<usize>()
                        })
                })
            {
                return false;
            }
            // Flat path: concatenate each worker's tensors, ring-reduce.
            let flat_inputs: Vec<Vec<f32>> = per_worker
                .iter()
                .map(|ts| ts.iter().flat_map(|t| t.clone()).collect())
                .collect();
            let flat_out = ring_mean_outputs(&flat_inputs);
            // Per-tensor path: serial mean of each tensor independently.
            let mut want = Vec::new();
            for ti in 0..shapes.len() {
                let inputs: Vec<Vec<f32>> =
                    (0..k).map(|w| per_worker[w][ti].clone()).collect();
                want.extend(serial_mean(&inputs));
            }
            flat_out.iter().all(|out| *out == want)
        },
    );
}
