//! Exhaustive property tests for [`persia::worker::elastic_assign`], the
//! rank→worker assignment the elastic embedding tier (`--ew-failover`)
//! rests on (ISSUE 8).
//!
//! The domain is small enough to enumerate completely: every worker count
//! up to 6, every dead-set bitmask, every rank up to 2× the worker count.
//! Properties checked:
//!
//! * **total** — an adopter exists whenever any worker is live;
//! * **deterministic + coordination-free** — a pure function of the inputs,
//!   insensitive to how the `dead` slice spells trailing live workers;
//! * **identity when healthy** — with no dead workers it is exactly the
//!   pre-elastic pinning `rank % n_workers`;
//! * **minimal movement** — killing one worker moves only the ranks that
//!   were assigned to it, and reviving one moves ranks only *onto* it.

use persia::worker::elastic_assign;

/// All dead-sets over `n` workers, as bool vectors (bitmask enumeration).
fn all_dead_sets(n: usize) -> Vec<Vec<bool>> {
    (0..1usize << n)
        .map(|mask| (0..n).map(|w| (mask >> w) & 1 == 1).collect())
        .collect()
}

#[test]
fn total_whenever_any_worker_is_live() {
    for n in 1..=6 {
        for dead in all_dead_sets(n) {
            let any_live = dead.iter().any(|d| !d);
            for rank in 0..2 * n {
                let got = elastic_assign(rank, n, &dead);
                if any_live {
                    let w = got.unwrap_or_else(|| {
                        panic!("no adopter for rank {rank}, n {n}, dead {dead:?}")
                    });
                    assert!(!dead[w], "rank {rank} assigned to dead worker {w}");
                } else {
                    assert_eq!(got, None, "all workers dead must yield None");
                }
            }
        }
    }
    assert_eq!(elastic_assign(3, 0, &[]), None, "an empty tier assigns nothing");
}

#[test]
fn deterministic_and_insensitive_to_trailing_live_spelling() {
    for n in 1..=6 {
        for dead in all_dead_sets(n) {
            for rank in 0..2 * n {
                let a = elastic_assign(rank, n, &dead);
                assert_eq!(a, elastic_assign(rank, n, &dead), "must be pure");
                // A shorter slice spells its missing tail as live.
                let trimmed: Vec<bool> = {
                    let last_dead = dead.iter().rposition(|&d| d).map(|i| i + 1).unwrap_or(0);
                    dead[..last_dead].to_vec()
                };
                assert_eq!(
                    a,
                    elastic_assign(rank, n, &trimmed),
                    "trailing-live spelling changed the assignment \
                     (rank {rank}, n {n}, dead {dead:?} vs {trimmed:?})"
                );
            }
        }
    }
}

#[test]
fn identity_when_all_workers_live() {
    for n in 1..=6 {
        for rank in 0..4 * n {
            assert_eq!(
                elastic_assign(rank, n, &vec![false; n]),
                Some(rank % n),
                "healthy tier must keep the pre-elastic pinning"
            );
        }
    }
}

#[test]
fn killing_one_worker_moves_only_its_ranks() {
    for n in 1..=6 {
        for dead in all_dead_sets(n) {
            for victim in 0..n {
                if dead[victim] {
                    continue;
                }
                let mut after = dead.clone();
                after[victim] = true;
                for rank in 0..2 * n {
                    let old = elastic_assign(rank, n, &dead).unwrap();
                    let new = elastic_assign(rank, n, &after);
                    if old == victim {
                        assert_ne!(
                            new,
                            Some(victim),
                            "rank {rank} left on the killed worker {victim}"
                        );
                    } else {
                        assert_eq!(
                            new,
                            Some(old),
                            "rank {rank} moved off live worker {old} when only \
                             {victim} died (n {n}, dead {dead:?})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn reviving_one_worker_moves_ranks_only_onto_it() {
    for n in 1..=6 {
        for dead in all_dead_sets(n) {
            for revived in 0..n {
                if !dead[revived] {
                    continue;
                }
                let mut after = dead.clone();
                after[revived] = false;
                for rank in 0..2 * n {
                    let old = elastic_assign(rank, n, &dead);
                    let new = elastic_assign(rank, n, &after).unwrap();
                    if new != revived {
                        assert_eq!(
                            Some(new),
                            old,
                            "rank {rank} moved between survivors when {revived} \
                             rejoined (n {n}, dead {dead:?})"
                        );
                    }
                }
            }
        }
    }
}
