//! Property tests for the recovery layer's durable artifacts: arbitrary,
//! truncated, or bit-flipped manifest bytes must NEVER panic the restore
//! path, and no amount of on-disk corruption may ever let resume observe a
//! **mixed-epoch** state (an epoch whose parts come from different steps).
//!
//! These are the §4.2.4 crash-restart inputs: a process that just died is
//! being rebuilt from whatever bytes survived. A panic here would take the
//! recovering process down a second time; accepting a half-written epoch
//! would silently splice two moments of the run together — both are pinned
//! as impossible.

use std::path::PathBuf;

use persia::config::{EmbeddingConfig, OptimizerKind, PartitionPolicy};
use persia::embedding::checkpoint::{decode_shard_manifest, encode_shard_manifest};
use persia::embedding::{CheckpointManager, EmbeddingPs};
use persia::recovery::{atomic_write, epoch_dir, latest_epoch, load_manifest, GlobalManifest};
use persia::util::quickcheck::forall;
use persia::util::Rng;

fn sample_manifest(step: u64, n_params: usize) -> GlobalManifest {
    GlobalManifest {
        step,
        fingerprint: 0xABCD_EF01,
        world: 2,
        loader_cursors: vec![step, step],
        opt_kind: 0,
        opt_t: step,
        params: (0..n_params).map(|i| i as f32 * 0.5 - 1.0).collect(),
        opt_m: Vec::new(),
        opt_v: Vec::new(),
        routing_epoch: 1,
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("persia_prop_rec_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Parsing must be total: `Ok` with a structurally consistent manifest, or
/// a clean `Err` — never a panic, never an inconsistent value.
fn parse_is_total(bytes: &[u8]) -> bool {
    match GlobalManifest::from_bytes(bytes) {
        Err(_) => true,
        Ok(m) => {
            m.world >= 1
                && m.loader_cursors.len() == m.world
                && m.loader_cursors.iter().all(|&c| c == m.step)
                && !m.params.is_empty()
                && (m.opt_m.is_empty() || m.opt_m.len() == m.params.len())
                && (m.opt_v.is_empty() || m.opt_v.len() == m.params.len())
        }
    }
}

#[test]
fn arbitrary_manifest_bytes_never_panic() {
    forall(
        11,
        400,
        |rng: &mut Rng| {
            let n = rng.below(400) as usize;
            let mut bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            // Half the time splice in the valid magic so the parse walks
            // past the header check.
            if rng.below(2) == 0 && bytes.len() >= 8 {
                bytes[..8].copy_from_slice(b"PRSAGM01");
            }
            bytes
        },
        |bytes| parse_is_total(bytes),
    )
}

#[test]
fn truncated_or_bitflipped_manifests_are_rejected_not_panicked() {
    let valid = sample_manifest(40, 17).to_bytes();
    forall(
        13,
        300,
        |rng: &mut Rng| {
            let mut bytes = valid.clone();
            if rng.below(2) == 0 {
                // Truncate anywhere.
                bytes.truncate(rng.below(bytes.len() as u64 + 1) as usize);
            } else {
                // Flip one bit anywhere.
                let i = rng.below(bytes.len() as u64) as usize;
                bytes[i] ^= 1 << rng.below(8);
            }
            bytes
        },
        |bytes| {
            if *bytes == valid {
                // A zero-effect mutation (flip picked a bit and flipped it
                // back is impossible, but truncation to full length is the
                // identity): must still parse to the original.
                GlobalManifest::from_bytes(bytes).is_ok()
            } else {
                // Every real mutation is caught by the length/magic/CRC
                // chain — and never panics.
                parse_is_total(bytes) && GlobalManifest::from_bytes(bytes).is_err()
            }
        },
    )
}

#[test]
fn manifest_roundtrip_is_exact() {
    forall(
        17,
        120,
        |rng: &mut Rng| {
            let step = rng.below(1000);
            let world = 1 + rng.below(4);
            let n = 1 + rng.below(40);
            let with_moments = rng.below(2);
            ((step, world), (n, with_moments))
        },
        |&((step, world), (n, with_moments))| {
            let world = world.clamp(1, 8) as usize;
            let n = n.clamp(1, 64) as usize;
            let mut rng = Rng::new(step ^ 0xC0FFEE);
            let m = GlobalManifest {
                step,
                fingerprint: rng.next_u64(),
                world,
                loader_cursors: vec![step; world],
                opt_kind: if with_moments == 1 { 2 } else { 0 },
                opt_t: rng.below(1 << 20),
                params: rng.normal_vec(n),
                opt_m: if with_moments == 1 { rng.normal_vec(n) } else { Vec::new() },
                opt_v: if with_moments == 1 { rng.normal_vec(n) } else { Vec::new() },
                routing_epoch: rng.below(4),
            };
            GlobalManifest::from_bytes(&m.to_bytes()).map(|back| back == m).unwrap_or(false)
        },
    )
}

#[test]
fn shard_manifest_codec_is_total() {
    let valid = encode_shard_manifest(24, &(1..3), true, 5);
    forall(
        19,
        300,
        |rng: &mut Rng| {
            if rng.below(3) == 0 {
                let n = rng.below(64) as usize;
                (0..n).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
            } else {
                let mut bytes = valid.clone();
                let i = rng.below(bytes.len() as u64) as usize;
                bytes[i] ^= 1 << rng.below(8);
                bytes
            }
        },
        |bytes| {
            if *bytes == valid {
                decode_shard_manifest(bytes).is_ok()
            } else {
                // Either rejected, or (random bytes happening to be valid —
                // practically impossible but allowed) a sane range.
                match decode_shard_manifest(bytes) {
                    Err(_) => true,
                    Ok((_, range, _, _)) => range.start < range.end,
                }
            }
        },
    )
}

/// The global anti-mixed-epoch guarantee: whatever single file corruption
/// happens, `latest_epoch` only ever yields an epoch whose global manifest
/// still parses — a half-committed or bit-flipped epoch falls through to
/// the previous fully committed one (or none), never to garbage.
#[test]
fn latest_epoch_survives_arbitrary_single_file_corruption() {
    forall(
        23,
        60,
        |rng: &mut Rng| (rng.below(3), rng.below(8), rng.below(64)),
        |&(which_epoch, byte_salt, flip)| {
            let root = tmp_dir("scan");
            for step in [10u64, 20, 30] {
                std::fs::create_dir_all(epoch_dir(&root, step)).unwrap();
                atomic_write(
                    &epoch_dir(&root, step).join("global.manifest"),
                    &sample_manifest(step, 9).to_bytes(),
                )
                .unwrap();
            }
            atomic_write(&root.join("LATEST"), b"30").unwrap();

            // Corrupt ONE epoch's manifest (flip a pseudo-random byte).
            let victim = [10u64, 20, 30][which_epoch as usize];
            let path = epoch_dir(&root, victim).join("global.manifest");
            let mut bytes = std::fs::read(&path).unwrap();
            let idx = (byte_salt as usize * 7 + flip as usize) % bytes.len();
            bytes[idx] ^= 0x20;
            std::fs::write(&path, &bytes).unwrap();

            let got = latest_epoch(&root);
            let ok = match victim {
                // LATEST points at 30; if 30 is corrupt the scan must fall
                // back to 20 (still fully committed), never error or yield
                // the corrupt one.
                30 => got == Some(20),
                // Otherwise 30 is intact and stays the answer.
                _ => got == Some(30),
            } && got.map(|s| load_manifest(&root, s).is_ok()).unwrap_or(false);
            std::fs::remove_dir_all(&root).ok();
            ok
        },
    )
}

/// The per-shard anti-mixed-epoch guarantee: a staged-but-uncommitted epoch
/// is invisible, and a committed epoch with a corrupted shard manifest
/// un-commits — restore always lands on one coherent step boundary.
#[test]
fn shard_restore_never_mixes_epochs() {
    let cfg = EmbeddingConfig {
        rows_per_group: 1 << 30,
        shard_capacity: 256,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let dir = tmp_dir("shard");
    let mgr = CheckpointManager::new(&dir).unwrap();
    let ps = EmbeddingPs::new(&cfg, 4, 3);
    let keys: Vec<(u32, u64)> = (0..24).map(|i| (0, i)).collect();
    let mut buf = vec![0.0; 96];
    ps.get_many(&keys, &mut buf);

    // Epoch 4: fully committed.
    ps.put_grads(&keys, &vec![0.5; 96]);
    let state_at_4: Vec<Vec<Vec<u8>>> = (0..2).map(|n| ps.snapshot_node(n).unwrap()).collect();
    mgr.prepare_epoch(&ps, 4).unwrap();
    mgr.commit_epoch(&ps, 4).unwrap();

    // Epoch 8: prepared, never committed (crash between the phases).
    ps.put_grads(&keys, &vec![0.5; 96]);
    mgr.prepare_epoch(&ps, 8).unwrap();

    // The staged epoch is invisible; restore lands on 4 exactly.
    assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(4));
    assert!(mgr.restore_epoch(&ps, 8).is_err(), "uncommitted epoch restored");
    ps.wipe_node(0).unwrap();
    ps.wipe_node(1).unwrap();
    mgr.restore_epoch(&ps, 4).unwrap();
    for n in 0..2 {
        assert_eq!(ps.snapshot_node(n).unwrap(), state_at_4[n], "node {n} not at epoch 4");
    }

    // Now commit 8, then corrupt ITS shard manifest: 8 un-commits, 4 stays.
    mgr.prepare_epoch(&ps, 8).unwrap();
    mgr.commit_epoch(&ps, 8).unwrap();
    assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(8));
    let mpath = dir.join("step-8").join("shard_0_2.manifest");
    let mut bytes = std::fs::read(&mpath).unwrap();
    let mid = bytes.len() - 3;
    bytes[mid] ^= 0x01;
    std::fs::write(&mpath, &bytes).unwrap();
    assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(4));
    std::fs::remove_dir_all(&dir).ok();
}
