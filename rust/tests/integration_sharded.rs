//! Multi-process sharded PS integration: N [`PsServer`]s each owning a
//! `--node-range` slice, driven through one [`ShardedRemotePs`] backend.
//!
//! Covers the ISSUE-2 acceptance drill end to end:
//! * a 3-shard loopback run matches the in-process PS within 1e-6 AUC/loss;
//! * killing one shard, restarting it, and restoring it from its snapshot
//!   lets training finish with all rows intact (both with in-process server
//!   instances and with real `persia serve-ps` child processes);
//! * merged stats (rows/evictions/imbalance) equal the in-process PS's;
//! * malformed deployments (overlap, gaps, config drift) are rejected at
//!   connect time.

use std::sync::Arc;

use persia::config::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, RecoveryConfig, ServiceConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::embedding::EmbeddingPs;
use persia::hybrid::Trainer;
use persia::service::{PsBackend, PsServer, PsServerHandle, ShardedRemotePs};

/// 4 PS nodes so they can be split across 3 shard processes (2 + 1 + 1).
const RANGES: [std::ops::Range<usize>; 3] = [0..2, 2..3, 3..4];

fn base_trainer(mode: TrainMode, steps: usize, nn_workers: usize) -> Trainer {
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 2,
        emb_dim_per_group: 8,
        nid_dim: 4,
        hidden: vec![16, 8],
        ids_per_group: 2,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 500,
        shard_capacity: 4096,
        n_nodes: 4,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster = ClusterConfig {
        n_nn_workers: nn_workers,
        n_emb_workers: 2,
        net: NetModelConfig::disabled(),
    };
    let train = TrainConfig {
        mode,
        batch_size: 32,
        lr: 0.1,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: 31,
        use_pjrt: false,
        compress: true,
    };
    let dataset = SyntheticDataset::new(&model, 500, 1.05, 31);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.eval_rows = 1024;
    t
}

/// One in-process shard server owning `range`, on an ephemeral port (or a
/// specific `addr` when restarting on a known port — retried briefly, since
/// rebinding a just-released port can race the old socket's teardown).
fn spawn_shard(t: &Trainer, range: std::ops::Range<usize>, addr: &str) -> (PsServerHandle, String) {
    let mut last_err = None;
    for _ in 0..40 {
        let ps = Arc::new(EmbeddingPs::new_range(
            &t.emb_cfg,
            t.model.emb_dim_per_group,
            t.train.seed,
            range.clone(),
        ));
        match PsServer::bind(ps, addr, &t.emb_cfg, t.train.seed) {
            Ok(server) => {
                let addr = server.local_addr().unwrap().to_string();
                return (server.spawn().unwrap(), addr);
            }
            Err(e) => {
                last_err = Some(e);
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
    panic!("could not bind shard server on {addr}: {:#}", last_err.unwrap());
}

fn spawn_three_shards(t: &Trainer) -> (Vec<PsServerHandle>, Vec<String>) {
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for range in RANGES {
        let (h, a) = spawn_shard(t, range, "127.0.0.1:0");
        handles.push(h);
        addrs.push(a);
    }
    (handles, addrs)
}

fn connect_sharded(addrs: &[String], reconnect_attempts: u32) -> Arc<ShardedRemotePs> {
    let cfg = ServiceConfig {
        addr: addrs.join(","),
        client_conns: 2,
        wire_compress: false,
        recovery: RecoveryConfig {
            attempts: reconnect_attempts,
            backoff_ms: 50,
            ..RecoveryConfig::default()
        },
    };
    Arc::new(ShardedRemotePs::connect(&cfg).unwrap())
}

/// The tentpole acceptance: a 3-shard-process loopback run is numerically
/// identical (≤ 1e-6 on AUC and every loss) to the in-process PS.
#[test]
fn three_shard_training_matches_in_process_within_1e6() {
    for mode in [TrainMode::Hybrid, TrainMode::FullSync] {
        let steps = 60;
        let mut local_t = base_trainer(mode, steps, 1);
        local_t.deterministic = true;
        let local = local_t.run_rust().unwrap();

        let mut remote_t = base_trainer(mode, steps, 1);
        remote_t.deterministic = true;
        let (handles, addrs) = spawn_three_shards(&remote_t);
        let backend = connect_sharded(&addrs, 1);
        assert_eq!(backend.n_shard_processes(), 3);
        remote_t.ps_backend = Some(backend.clone());
        let remote = remote_t.run_rust().unwrap();

        let auc_local = local.report.final_auc.unwrap();
        let auc_remote = remote.report.final_auc.unwrap();
        assert!(
            (auc_local - auc_remote).abs() <= 1e-6,
            "{mode:?}: AUC {auc_local} (local) vs {auc_remote} (3-shard)"
        );
        assert_eq!(local.tracker.losses.len(), remote.tracker.losses.len());
        for ((sa, la), (sb, lb)) in local.tracker.losses.iter().zip(&remote.tracker.losses) {
            assert_eq!(sa, sb);
            assert!((la - lb).abs() <= 1e-6, "{mode:?} step {sa}: loss {la} vs {lb}");
        }
        // The run meaningfully trained.
        let early: f32 = local.tracker.losses[..10].iter().map(|(_, l)| l).sum::<f32>() / 10.0;
        assert!(local.tracker.recent_loss(10).unwrap() < early, "{mode:?} did not learn");

        drop(remote_t);
        drop(backend);
        for h in handles {
            h.shutdown().unwrap();
        }
    }
}

/// Concurrent paths (async appliers, 2 NN workers) drive the scatter-gather
/// client without deadlock or data mixups.
#[test]
fn concurrent_training_over_three_shards() {
    let steps = 50;
    let mut t = base_trainer(TrainMode::Hybrid, steps, 2);
    t.train.eval_every = 0;
    let (handles, addrs) = spawn_three_shards(&t);
    let backend = connect_sharded(&addrs, 1);
    t.ps_backend = Some(backend.clone());
    let out = t.run_rust().unwrap();
    assert_eq!(out.report.steps, steps as u64);
    let early: f32 = out.tracker.losses[..10].iter().map(|(_, l)| l).sum::<f32>() / 10.0;
    assert!(out.tracker.recent_loss(10).unwrap() < early, "loss did not drop over 3 shards");
    assert_eq!(out.report.grad_put_failures, 0, "puts failed against healthy shards");
    drop(t);
    drop(backend);
    for h in handles {
        h.shutdown().unwrap();
    }
}

/// Merged stats equal the in-process PS fed the exact same traffic — row and
/// eviction counts sum, and the imbalance is computed over the *summed*
/// per-node traffic, not averaged per process.
#[test]
fn sharded_stats_merge_to_in_process_values() {
    let t = base_trainer(TrainMode::FullSync, 1, 1);
    let mirror = EmbeddingPs::new(&t.emb_cfg, t.model.emb_dim_per_group, t.train.seed);
    let (handles, addrs) = spawn_three_shards(&t);
    let backend = connect_sharded(&addrs, 1);

    let keys: Vec<(u32, u64)> = (0..300).map(|i| (i as u32 % 2, (i * 13) as u64)).collect();
    let mut rows = vec![0.0f32; keys.len() * 8];
    backend.get_many(&keys, &mut rows).unwrap();
    let mut mirror_rows = vec![0.0f32; keys.len() * 8];
    mirror.get_many(&keys, &mut mirror_rows);
    assert_eq!(rows, mirror_rows, "3-shard rows differ from in-process rows");
    backend.put_grads(&keys, &vec![0.5; keys.len() * 8]).unwrap();
    mirror.put_grads(&keys, &vec![0.5; keys.len() * 8]);

    let merged = backend.stats().unwrap();
    assert_eq!(merged.total_rows, mirror.total_rows());
    assert_eq!(merged.total_evictions, mirror.total_evictions());
    assert!(
        (merged.imbalance - mirror.imbalance()).abs() < 1e-12,
        "merged imbalance {} != in-process {}",
        merged.imbalance,
        mirror.imbalance()
    );

    drop(backend);
    for h in handles {
        h.shutdown().unwrap();
    }
}

/// The §4.2.4 recovery drill, cross-process: snapshot a shard's nodes over
/// the wire, kill the shard, restart it empty on the same port, restore it
/// from the snapshot, and finish training — all rows intact and the final
/// numbers identical to an uninterrupted in-process run.
#[test]
fn kill_one_shard_restore_from_snapshot_training_continues() {
    let phase = 30;

    // Uninterrupted reference: two training phases against one PS.
    let local_ps = {
        let t = base_trainer(TrainMode::Hybrid, phase, 1);
        Arc::new(EmbeddingPs::new(&t.emb_cfg, t.model.emb_dim_per_group, t.train.seed))
    };
    let run_local = || {
        let mut t = base_trainer(TrainMode::Hybrid, phase, 1);
        t.deterministic = true;
        t.ps_backend = Some(local_ps.clone());
        t.run_rust().unwrap()
    };
    let _local1 = run_local();
    let rows_after_phase1 = local_ps.total_rows();
    let local2 = run_local();

    // Sharded run, phase 1.
    let template = base_trainer(TrainMode::Hybrid, phase, 1);
    let (mut handles, addrs) = spawn_three_shards(&template);
    // Generous retry budget: phase 2 must ride out the restarted shard.
    let backend = connect_sharded(&addrs, 20);
    let mut t1 = base_trainer(TrainMode::Hybrid, phase, 1);
    t1.deterministic = true;
    t1.ps_backend = Some(backend.clone());
    t1.run_rust().unwrap();
    assert_eq!(
        backend.stats().unwrap().total_rows,
        rows_after_phase1,
        "sharded phase-1 state diverged from reference"
    );

    // Snapshot the victim shard's node over the wire, then kill the shard.
    let victim_node = 2; // RANGES[1] owns exactly node 2
    let snap = backend.snapshot_node(victim_node).unwrap();
    assert_eq!(snap.hot.len(), template.emb_cfg.shards_per_node);
    assert!(snap.cold.is_none(), "all-hot shard must not report a cold tier");
    handles.remove(1).shutdown().unwrap();

    // Restart it on the same port — fresh process, empty state — and
    // restore its node from the snapshot (client reconnects transparently).
    let (new_handle, new_addr) = spawn_shard(&template, RANGES[1].clone(), &addrs[1]);
    assert_eq!(new_addr, addrs[1]);
    handles.insert(1, new_handle);
    backend.restore_node(victim_node, &snap).unwrap();
    assert_eq!(
        backend.stats().unwrap().total_rows,
        rows_after_phase1,
        "rows lost across kill/restore"
    );

    // Phase 2 trains to the exact same numbers as the uninterrupted run.
    let mut t2 = base_trainer(TrainMode::Hybrid, phase, 1);
    t2.deterministic = true;
    t2.ps_backend = Some(backend.clone());
    let remote2 = t2.run_rust().unwrap();
    let auc_local = local2.report.final_auc.unwrap();
    let auc_remote = remote2.report.final_auc.unwrap();
    assert!(
        (auc_local - auc_remote).abs() <= 1e-6,
        "post-recovery AUC {auc_remote} != uninterrupted {auc_local}"
    );
    for ((sa, la), (sb, lb)) in local2.tracker.losses.iter().zip(&remote2.tracker.losses) {
        assert_eq!(sa, sb);
        assert!((la - lb).abs() <= 1e-6, "step {sa}: loss {la} vs {lb} after recovery");
    }

    drop(t1);
    drop(t2);
    drop(backend);
    for h in handles {
        h.shutdown().unwrap();
    }
}

/// Deployment mistakes fail loudly at connect time: node-range overlap,
/// uncovered nodes, and config drift between shard processes.
#[test]
fn malformed_shard_deployments_rejected_at_connect() {
    let t = base_trainer(TrainMode::Hybrid, 1, 1);
    let connect_err = |addrs: &[String]| {
        let cfg = ServiceConfig {
            addr: addrs.join(","),
            client_conns: 1,
            wire_compress: false,
            recovery: RecoveryConfig { attempts: 0, backoff_ms: 1, ..RecoveryConfig::default() },
        };
        match ShardedRemotePs::connect(&cfg) {
            Ok(_) => panic!("malformed deployment {addrs:?} accepted"),
            Err(e) => format!("{e:#}"),
        }
    };

    // Overlap: two full-range servers.
    let (h1, a1) = spawn_shard(&t, 0..4, "127.0.0.1:0");
    let (h2, a2) = spawn_shard(&t, 0..4, "127.0.0.1:0");
    let err = connect_err(&[a1.clone(), a2]);
    assert!(err.contains("owned by both"), "wrong overlap error: {err}");
    h2.shutdown().unwrap();

    // Gap: a partial shard alone leaves nodes unserved.
    let (h3, a3) = spawn_shard(&t, 0..2, "127.0.0.1:0");
    let err = connect_err(&[a3.clone()]);
    assert!(err.contains("not served by any"), "wrong gap error: {err}");

    // Drift: same topology, different seed => different numerics.
    let mut t_drift = base_trainer(TrainMode::Hybrid, 1, 1);
    t_drift.train.seed += 1;
    let (h4, a4) = spawn_shard(&t_drift, 2..4, "127.0.0.1:0");
    let err = connect_err(&[a3, a4]);
    assert!(err.contains("disagrees"), "wrong drift error: {err}");

    h1.shutdown().unwrap();
    h3.shutdown().unwrap();
    h4.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// True multi-process drill: real `persia serve-ps` child processes.
// ---------------------------------------------------------------------------

mod multiprocess {
    use super::*;
    use persia::config::BenchPreset;
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};
    use std::time::Duration;

    const PRESET: &str = "taobao";
    const DENSE: &str = "tiny";
    const CAPACITY: &str = "2048";
    const SEED: u64 = 42;

    /// A serve-ps child plus the concrete address it reported.
    struct ShardProc {
        child: Child,
        addr: String,
    }

    impl Drop for ShardProc {
        fn drop(&mut self) {
            let _ = self.child.kill();
            let _ = self.child.wait();
        }
    }

    /// Spawn `persia serve-ps` and wait for its "listening on ADDR" line.
    /// Retries the spawn: restarting on a just-released port can race the
    /// old socket's teardown.
    fn spawn_ps_process(addr: &str, node_range: &str) -> ShardProc {
        let exe = env!("CARGO_BIN_EXE_persia");
        for attempt in 0..20u64 {
            let mut child = Command::new(exe)
                .args([
                    "serve-ps",
                    "--preset",
                    PRESET,
                    "--dense",
                    DENSE,
                    "--shard-capacity",
                    CAPACITY,
                    "--seed",
                    &SEED.to_string(),
                    "--addr",
                    addr,
                    "--node-range",
                    node_range,
                ])
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .expect("spawn persia serve-ps");
            let stdout = child.stdout.take().expect("child stdout piped");
            let mut reader = std::io::BufReader::new(stdout);
            let mut listening = None;
            let mut line = String::new();
            loop {
                line.clear();
                match reader.read_line(&mut line) {
                    Ok(0) | Err(_) => break, // EOF: child died (port race?)
                    Ok(_) => {
                        if let Some(rest) = line.strip_prefix("listening on ") {
                            let a = rest.split_whitespace().next().unwrap_or("").to_string();
                            if !a.is_empty() {
                                listening = Some(a);
                            }
                            break;
                        }
                    }
                }
            }
            match listening {
                Some(a) => return ShardProc { child, addr: a },
                None => {
                    let _ = child.kill();
                    let _ = child.wait();
                    std::thread::sleep(Duration::from_millis(100 + 50 * attempt));
                }
            }
        }
        panic!("persia serve-ps would not start on {addr} ({node_range})");
    }

    /// A trainer built from the *same preset pipeline* `serve-ps` uses, so
    /// the config fingerprints provably agree with the child processes.
    fn preset_trainer(steps: usize) -> Trainer {
        let preset = BenchPreset::by_name(PRESET).unwrap();
        let model = preset.model(DENSE);
        let emb_cfg = preset.embedding(&model, CAPACITY.parse().unwrap());
        let rows = preset.embedding(&model, 1).rows_per_group;
        let cluster = ClusterConfig {
            n_nn_workers: 1,
            n_emb_workers: 2,
            net: NetModelConfig::disabled(),
        };
        let train = TrainConfig {
            mode: TrainMode::Hybrid,
            batch_size: 32,
            lr: 0.05,
            staleness_bound: 4,
            steps,
            eval_every: steps,
            seed: SEED,
            use_pjrt: false,
            compress: false,
        };
        let dataset = SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED);
        let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
        t.eval_rows = 512;
        t.deterministic = true;
        t
    }

    /// The acceptance drill against *real processes*: 3 `serve-ps` children,
    /// parity with in-process, kill one child mid-sequence, restart it from
    /// nothing, restore its node slice from a wire snapshot, finish.
    #[test]
    fn three_process_drill_with_kill_and_restore() {
        let phase = 20;

        // Reference: two uninterrupted phases in-process.
        let t0 = preset_trainer(phase);
        let local_ps =
            Arc::new(EmbeddingPs::new(&t0.emb_cfg, t0.model.emb_dim_per_group, t0.train.seed));
        let run_local = || {
            let mut t = preset_trainer(phase);
            t.ps_backend = Some(local_ps.clone());
            t.run_rust().unwrap()
        };
        let _local1 = run_local();
        let rows_after_phase1 = local_ps.total_rows();
        let local2 = run_local();

        // 3 real shard processes over the preset's 4 nodes.
        let mut procs = vec![
            spawn_ps_process("127.0.0.1:0", "0..2"),
            spawn_ps_process("127.0.0.1:0", "2..3"),
            spawn_ps_process("127.0.0.1:0", "3..4"),
        ];
        let addrs: Vec<String> = procs.iter().map(|p| p.addr.clone()).collect();
        let cfg = ServiceConfig {
            addr: addrs.join(","),
            client_conns: 2,
            wire_compress: false,
            recovery: RecoveryConfig {
                attempts: 30,
                backoff_ms: 100,
                ..RecoveryConfig::default()
            },
        };
        let backend = Arc::new(ShardedRemotePs::connect(&cfg).unwrap());

        // Phase 1 against the processes.
        let mut t1 = preset_trainer(phase);
        t1.ps_backend = Some(backend.clone());
        t1.run_rust().unwrap();
        assert_eq!(
            backend.stats().unwrap().total_rows,
            rows_after_phase1,
            "process-sharded phase 1 diverged from in-process reference"
        );

        // Snapshot node 2 over the wire, then SIGKILL its owner process.
        let snap = backend.snapshot_node(2).unwrap();
        let dead_addr = procs[1].addr.clone();
        procs[1].child.kill().expect("kill shard process");
        let _ = procs[1].child.wait();

        // Restart the same slice on the same port, then restore its node.
        procs[1] = spawn_ps_process(&dead_addr, "2..3");
        assert_eq!(procs[1].addr, dead_addr, "restarted shard moved ports");
        backend.restore_node(2, &snap).unwrap();
        assert_eq!(
            backend.stats().unwrap().total_rows,
            rows_after_phase1,
            "rows lost across process kill/restore"
        );

        // Phase 2 finishes and matches the uninterrupted reference exactly.
        let mut t2 = preset_trainer(phase);
        t2.ps_backend = Some(backend.clone());
        let remote2 = t2.run_rust().unwrap();
        let auc_local = local2.report.final_auc.unwrap();
        let auc_remote = remote2.report.final_auc.unwrap();
        assert!(
            (auc_local - auc_remote).abs() <= 1e-6,
            "post-recovery AUC {auc_remote} != uninterrupted {auc_local}"
        );
        for ((sa, la), (sb, lb)) in local2.tracker.losses.iter().zip(&remote2.tracker.losses) {
            assert_eq!(sa, sb);
            assert!((la - lb).abs() <= 1e-6, "step {sa}: loss {la} vs {lb}");
        }

        // Graceful teardown; Drop kills any survivor regardless.
        drop(t1);
        drop(t2);
        backend.shutdown_all().unwrap();
        for p in &mut procs {
            let _ = p.child.wait();
        }
    }
}
