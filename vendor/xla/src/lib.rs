//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build image used for CI has no XLA/PJRT shared library, so this crate
//! provides the exact API surface `persia::runtime` compiles against:
//!
//! * [`Literal`] is **functional**: it is a plain host buffer with shape and
//!   element-type checking, so literal construction/round-trip code (and its
//!   tests) behave exactly like the real crate.
//! * [`PjRtClient::cpu`] returns an error — there is no compiler/executor
//!   behind it. Every downstream object (`PjRtLoadedExecutable`, …) is only
//!   reachable through a client, so executable paths fail fast at the one
//!   place the runtime already handles (`PjRtRuntime::cpu()?`), and the
//!   PJRT-dependent tests skip themselves.
//!
//! Deploying against a real XLA build is a one-line swap of this path
//! dependency for the real `xla` crate in the workspace manifest.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `xla::Error` usage (`Display`).
pub struct Error(String);

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    fn stub(what: &str) -> Self {
        Error(format!("{what}: PJRT unavailable (offline xla stub; link the real xla crate)"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Element types the runtime constructs literals with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

impl ElementType {
    fn elem_size(self) -> usize {
        match self {
            ElementType::F32 => 4,
        }
    }
}

/// Host element types a [`Literal`] can be viewed as.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
}

/// A host tensor: shape + element type + raw little-endian bytes.
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if elems * ty.elem_size() != data.len() {
            return Err(Error(format!(
                "shape {dims:?} needs {} bytes, got {}",
                elems * ty.elem_size(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Copy the buffer out as host elements.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ELEMENT_TYPE != self.ty {
            return Err(Error(format!("element type mismatch: literal is {:?}", self.ty)));
        }
        let n = self.element_count();
        let mut out = Vec::with_capacity(n);
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.bytes.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                self.bytes.len(),
            );
            out.set_len(n);
        }
        Ok(out)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".to_string()))
    }

    /// Refill the buffer in place from host elements (shape unchanged).
    pub fn copy_raw_from<T: NativeType>(&mut self, src: &[T]) -> Result<()> {
        if T::ELEMENT_TYPE != self.ty || src.len() != self.element_count() {
            return Err(Error(format!(
                "copy_raw_from: {} elements into literal of {}",
                src.len(),
                self.element_count()
            )));
        }
        unsafe {
            std::ptr::copy_nonoverlapping(
                src.as_ptr() as *const u8,
                self.bytes.as_mut_ptr(),
                self.bytes.len(),
            );
        }
        Ok(())
    }

    /// Decompose a tuple literal. Stub literals are never tuples; this is
    /// only reachable through an executable, which the stub cannot produce.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// PJRT client. The stub cannot execute, so construction fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// Compiled executable (unreachable without a client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer (unreachable without a client).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_shape_check() {
        let data: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0].iter().flat_map(|x| x.to_le_bytes()).collect();
        let mut lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &data).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        lit.copy_raw_from(&[5.0f32, 6.0, 7.0, 8.0]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![5.0, 6.0, 7.0, 8.0]);
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0u8; 4])
            .is_err());
        assert!(lit.copy_raw_from(&[1.0f32]).is_err());
    }

    #[test]
    fn client_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"), "{msg}");
    }
}
