//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored shim provides
//! the exact API surface the `persia` crate uses — `Error`, `Result`,
//! `Context::{context, with_context}` on both `Result` and `Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros — with the same semantics:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * context wraps outermost-first, and `Display`/`Debug` render the whole
//!   chain as `outer: inner: root`, so `format!("{err:#}")` contains every
//!   layer (a superset of real anyhow's `{:#}` behaviour);
//! * `Error` is `Send + Sync` and deliberately does **not** implement
//!   `std::error::Error`, which is what makes the blanket `From` impl
//!   coherent — the same trick real anyhow uses.
//!
//! Swapping back to crates.io anyhow is a one-line change in the workspace
//! manifest; no call sites need to change.

use std::fmt;

/// Error type: an ordered context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Attach a context message to the error path.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily evaluated context message to the error path.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_outermost_first() {
        let err = io_fail().context("loading config").unwrap_err();
        let text = format!("{err:#}");
        assert!(text.starts_with("loading config: "), "{text}");
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let err = x.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(err.to_string(), "missing key");
    }

    #[test]
    fn macros_build_errors() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            ensure!(flag);
            if !flag {
                bail!("unreachable {}", 1);
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        assert_eq!(inner(false).unwrap_err().to_string(), "flag was false");
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
    }
}
