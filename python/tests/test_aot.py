"""AOT emission sanity: HLO text is produced, parseable-looking, complete."""

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_presets_well_formed():
    for p in aot.PRESETS.values():
        assert p.dims[0] == p.emb_dim + p.nid_dim
        assert p.dims[-1] == 1
        assert p.emb_dim == p.n_groups * p.emb_dim_per_group


def test_paper_preset_matches_table1_dense_scale():
    # Table 1: every benchmark uses a ~12M dense-parameter FFNN
    # (hidden 4096/2048/1024/512/256).
    p = aot.PRESETS["paper"]
    n = model.param_count(p.dims)
    assert 11_000_000 < n < 13_000_000, n
    assert p.hidden == (4096, 2048, 1024, 512, 256)


def _entry_param_count(text: str) -> int:
    entry = text[text.index("ENTRY") :]
    entry = entry[: entry.index("\n}")]
    return entry.count("parameter(")


def test_lower_train_tiny_emits_hlo_text():
    text = aot.lower_train(aot.PRESETS["tiny"])
    assert "HloModule" in text
    assert "ENTRY" in text
    # 2 hidden + 1 out layer => 6 param tensors + emb + nid + y = 9 inputs.
    assert _entry_param_count(text) == 9


def test_lower_forward_tiny_emits_hlo_text():
    text = aot.lower_forward(aot.PRESETS["tiny"])
    assert "HloModule" in text
    assert _entry_param_count(text) == 8


def test_lower_kernels_emit_hlo_text():
    assert "HloModule" in aot.lower_bag((8, 4, 3))
    assert "HloModule" in aot.lower_compress((8, 4))
    assert "HloModule" in aot.lower_decompress((8, 4))


def test_manifest_mentions_every_preset():
    text = aot.manifest_text()
    for name in aot.PRESETS:
        assert f"[{name}]" in text
        assert f"train_{name}.hlo.txt" in text
    assert "format_version = 1" in text


def test_pallas_and_plain_lowerings_agree_numerically():
    # The exported artifact (pallas) and the plain tower must be the same
    # function: evaluate both lowered forms via jax and compare.
    import jax

    p = aot.PRESETS["tiny"]
    n_layers = len(p.dims) - 1
    key = jax.random.PRNGKey(7)
    params = model.init_params(key, p.dims)
    args = []
    for w, b in params:
        args += [w, b]
    ke, kn, kyy = jax.random.split(key, 3)
    args.append(jax.random.normal(ke, (p.batch, p.emb_dim)))
    args.append(jax.random.normal(kn, (p.batch, p.nid_dim)))
    args.append((jax.random.uniform(kyy, (p.batch,)) > 0.5).astype(jnp.float32))

    out_p = model.train_step_flat(n_layers, use_pallas=True)(*args)
    out_j = model.train_step_flat(n_layers, use_pallas=False)(*args)
    assert len(out_p) == len(out_j) == 2 * n_layers + 2
    for a, b in zip(out_p, out_j):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
