"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel is swept over shapes and dtypes with hypothesis and
asserted allclose against the pure-jnp oracle in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.compress import KAPPA, compress, decompress
from compile.kernels.embedding_bag import embedding_bag
from compile.kernels.fused_mlp import fused_linear, vmem_footprint_bytes

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32) * 3.0
    return x.astype(dtype)


# ---------------------------------------------------------------------- mlp
@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    activation=st.sampled_from(["relu", "none", "sigmoid"]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, activation, dtype, seed):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(kx, (m, k), dtype)
    w = _rand(kw, (k, n), dtype)
    b = _rand(kb, (n,), dtype)
    got = fused_linear(x, w, b, activation=activation, block_m=32, block_n=32, block_k=32)
    want = ref.fused_linear_ref(x, w, b, activation=activation)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_fused_linear_tile_aligned_exact():
    # A shape that exactly matches the tile grid (no padding path).
    key = jax.random.PRNGKey(0)
    kx, kw, kb = jax.random.split(key, 3)
    x = _rand(kx, (64, 96), jnp.float32)
    w = _rand(kw, (96, 32), jnp.float32)
    b = _rand(kb, (32,), jnp.float32)
    got = fused_linear(x, w, b, block_m=32, block_n=32, block_k=32)
    np.testing.assert_allclose(got, ref.fused_linear_ref(x, w, b), rtol=1e-4, atol=1e-4)


def test_fused_linear_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 7))
    b = jnp.zeros((7,))
    with pytest.raises(ValueError):
        fused_linear(x, w, b)


def test_fused_linear_relu_clamps_negative():
    x = -jnp.ones((4, 4))
    w = jnp.eye(4)
    b = jnp.zeros((4,))
    out = fused_linear(x, w, b, activation="relu")
    assert float(jnp.max(out)) == 0.0


def test_vmem_footprint_within_budget():
    # Default MXU blocks must fit a 16 MiB VMEM with double-buffering room.
    assert vmem_footprint_bytes() < 16 * 1024 * 1024


# ---------------------------------------------------------------------- bag
@settings(**SETTINGS)
@given(
    b=st.integers(1, 40),
    l=st.integers(1, 20),
    d=st.integers(1, 40),
    mode=st.sampled_from(["sum", "mean"]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_embedding_bag_matches_ref(b, l, d, mode, dtype, seed):
    x = _rand(jax.random.PRNGKey(seed), (b, l, d), dtype)
    got = embedding_bag(x, mode=mode, block_b=8)
    want = ref.embedding_bag_ref(x, mode=mode)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_embedding_bag_blocked_l_accumulation():
    x = jnp.arange(2 * 8 * 3, dtype=jnp.float32).reshape(2, 8, 3)
    got = embedding_bag(x, mode="sum", block_b=2, block_l=2)
    np.testing.assert_allclose(got, ref.embedding_bag_ref(x), rtol=1e-6)


def test_embedding_bag_rejects_bad_rank():
    with pytest.raises(ValueError):
        embedding_bag(jnp.zeros((3, 4)))


# ----------------------------------------------------------------- compress
@settings(**SETTINGS)
@given(
    r=st.integers(1, 60),
    d=st.integers(1, 40),
    scale=st.floats(1e-6, 1e6),
    seed=st.integers(0, 2**31 - 1),
)
def test_compress_roundtrip_error_bound(r, d, scale, seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (r, d), jnp.float32) * scale
    vals, scales = compress(v, block_rows=16)
    back = decompress(vals, scales, block_rows=16)
    # Relative error per row bounded by fp16 resolution of the scaled block:
    # |v - back| <= ||v||_inf / KAPPA * (KAPPA * eps16) ~ ||v||_inf * 2^-10.
    norms = np.max(np.abs(np.asarray(v)), axis=-1, keepdims=True)
    bound = norms * 2.0**-10 + 1e-30
    assert np.all(np.abs(np.asarray(back) - np.asarray(v)) <= bound)


@settings(**SETTINGS)
@given(r=st.integers(1, 40), d=st.integers(1, 30), seed=st.integers(0, 2**31 - 1))
def test_compress_matches_ref(r, d, seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (r, d), jnp.float32)
    vals, scales = compress(v, block_rows=8)
    rvals, rscales = ref.compress_ref(v)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_allclose(scales, rscales, rtol=1e-6)


def test_compress_zero_rows_exact():
    v = jnp.zeros((5, 7))
    vals, scales = compress(v)
    back = decompress(vals, scales)
    np.testing.assert_array_equal(np.asarray(back), np.zeros((5, 7), np.float32))


def test_compress_survives_fp16_overflow_range():
    # Values far above fp16 max must round-trip thanks to the scaling.
    v = jnp.array([[1e8, -3e7, 5e6]], jnp.float32)
    back = decompress(*compress(v))
    np.testing.assert_allclose(back, v, rtol=2e-3)


def test_kappa_under_fp16_max():
    assert KAPPA < 65504.0
