"""L2 model correctness: shapes, gradients (vs numerical diff), pallas parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _setup(batch=8, emb_dim=12, nid_dim=4, hidden=(16, 8), seed=0):
    dims = model.layer_dims(emb_dim, nid_dim, hidden)
    key = jax.random.PRNGKey(seed)
    kp, ke, kn, ky = jax.random.split(key, 4)
    params = model.init_params(kp, dims)
    emb = jax.random.normal(ke, (batch, emb_dim))
    nid = jax.random.normal(kn, (batch, nid_dim))
    y = (jax.random.uniform(ky, (batch,)) > 0.5).astype(jnp.float32)
    return params, emb, nid, y, dims


def test_layer_dims_and_param_count():
    dims = model.layer_dims(128, 16, (256, 128, 64))
    assert dims == [144, 256, 128, 64, 1]
    assert model.param_count(dims) == (
        144 * 256 + 256 + 256 * 128 + 128 + 128 * 64 + 64 + 64 * 1 + 1
    )


def test_forward_shapes_and_range():
    params, emb, nid, _, _ = _setup()
    probs = model.forward(params, emb, nid, use_pallas=False)
    assert probs.shape == (8,)
    assert np.all((np.asarray(probs) > 0) & (np.asarray(probs) < 1))


def test_pallas_tower_matches_plain_jnp():
    params, emb, nid, y, _ = _setup(batch=16, emb_dim=24, nid_dim=8, hidden=(32, 16))
    lp = model.loss_fn(params, emb, nid, y, use_pallas=True)
    lj = model.loss_fn(params, emb, nid, y, use_pallas=False)
    np.testing.assert_allclose(lp, lj, rtol=1e-5, atol=1e-6)
    pp = model.forward(params, emb, nid, use_pallas=True)
    pj = model.forward(params, emb, nid, use_pallas=False)
    np.testing.assert_allclose(pp, pj, rtol=1e-5, atol=1e-6)


def test_train_step_outputs():
    params, emb, nid, y, _ = _setup()
    loss, gparams, gemb = model.train_step(params, emb, nid, y, use_pallas=False)
    assert loss.shape == ()
    assert gemb.shape == emb.shape
    assert len(gparams) == len(params)
    for (gw, gb), (w, b) in zip(gparams, params):
        assert gw.shape == w.shape and gb.shape == b.shape


def test_gradients_match_numerical():
    params, emb, nid, y, _ = _setup(batch=4, emb_dim=6, nid_dim=3, hidden=(8,))
    _, gparams, gemb = model.train_step(params, emb, nid, y, use_pallas=False)

    def loss_at(e):
        return float(model.loss_fn(params, e, nid, y, use_pallas=False))

    eps = 1e-3
    e_np = np.asarray(emb)
    for idx in [(0, 0), (1, 3), (3, 5)]:
        ep = e_np.copy()
        ep[idx] += eps
        em = e_np.copy()
        em[idx] -= eps
        num = (loss_at(jnp.asarray(ep)) - loss_at(jnp.asarray(em))) / (2 * eps)
        np.testing.assert_allclose(np.asarray(gemb)[idx], num, rtol=2e-2, atol=1e-4)

    # One dense weight too.
    w0 = np.asarray(params[0][0])

    def loss_w(wnew):
        p2 = [(jnp.asarray(wnew), params[0][1])] + params[1:]
        return float(model.loss_fn(p2, emb, nid, y, use_pallas=False))

    wp = w0.copy()
    wp[0, 0] += eps
    wm = w0.copy()
    wm[0, 0] -= eps
    num = (loss_w(wp) - loss_w(wm)) / (2 * eps)
    np.testing.assert_allclose(np.asarray(gparams[0][0])[0, 0], num, rtol=2e-2, atol=1e-4)


def test_pallas_gradients_match_plain():
    params, emb, nid, y, _ = _setup(batch=8, emb_dim=8, nid_dim=4, hidden=(16,))
    _, gp_p, ge_p = model.train_step(params, emb, nid, y, use_pallas=True)
    _, gp_j, ge_j = model.train_step(params, emb, nid, y, use_pallas=False)
    np.testing.assert_allclose(ge_p, ge_j, rtol=1e-4, atol=1e-5)
    for (a, ab), (b, bb) in zip(gp_p, gp_j):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(ab, bb, rtol=1e-4, atol=1e-5)


def test_bce_loss_matches_manual():
    logits = jnp.array([0.5, -1.0, 2.0])
    y = jnp.array([1.0, 0.0, 1.0])
    want = -np.mean(
        np.asarray(y) * np.log(1 / (1 + np.exp(-np.asarray(logits))))
        + (1 - np.asarray(y)) * np.log(1 - 1 / (1 + np.exp(-np.asarray(logits))))
    )
    np.testing.assert_allclose(model.bce_loss(logits, y), want, rtol=1e-6)


def test_loss_decreases_under_sgd():
    params, emb, nid, y, _ = _setup(batch=32, emb_dim=8, nid_dim=4, hidden=(16, 8), seed=3)
    lr = 0.5
    losses = []
    for _ in range(20):
        loss, gparams, gemb = model.train_step(params, emb, nid, y, use_pallas=False)
        losses.append(float(loss))
        params = [
            (w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(params, gparams)
        ]
        emb = emb - lr * gemb
    assert losses[-1] < losses[0] * 0.7, losses
