"""L1 Pallas kernel: embedding-bag pooling ``[B, L, D] -> [B, D]``.

Persia's embedding workers aggregate the per-sample list of looked-up
embedding rows into one pooled vector per feature group (paper §4.1 step 4,
"the embedding worker performs some potential aggregation of original
embedding vectors"). On the CPU workers this is a segment-sum; the TPU-idiom
version keeps a [block_b, L, D] slab VMEM-resident and reduces over the bag
axis — no gather/scatter, the (already gathered) rows stream in via the
BlockSpec schedule.

Supports sum and mean pooling. interpret=True as everywhere (see fused_mlp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 64


def _bag_kernel(x_ref, o_ref, *, l_steps: int, mode: str, bag_len: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...].astype(o_ref.dtype), axis=1)

    if mode == "mean":

        @pl.when(pl.program_id(1) == l_steps - 1)
        def _finalize():
            o_ref[...] = o_ref[...] / bag_len


@functools.partial(jax.jit, static_argnames=("mode", "block_b", "block_l"))
def embedding_bag(x, mode: str = "sum", block_b: int = BLOCK_B, block_l: int = 0):
    """Pool the bag axis of ``x: [B, L, D]`` to ``[B, D]`` (sum or mean)."""
    if mode not in ("sum", "mean"):
        raise ValueError(f"unknown mode: {mode}")
    if x.ndim != 3:
        raise ValueError(f"expected [B, L, D], got {x.shape}")
    b, l, d = x.shape
    bb = min(block_b, max(1, b))
    bl = l if block_l <= 0 else min(block_l, l)

    # Pad B up to the block grid; L up to a multiple of bl. Padding rows are
    # zero so they do not perturb the sum; mean divides by the true bag_len.
    pb = (-b) % bb
    plen = (-l) % bl
    xp = jnp.pad(x, ((0, pb), (0, plen), (0, 0)))
    bp, lp, _ = xp.shape
    l_steps = lp // bl
    grid = (bp // bb, l_steps)

    out = pl.pallas_call(
        functools.partial(_bag_kernel, l_steps=l_steps, mode=mode, bag_len=l),
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bl, d), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d), jnp.float32),
        interpret=True,
    )(xp)
    return out[:b]
