"""L1 Pallas kernel: fused tiled ``act(x @ W + b)``.

This is the compute hot-spot of the dense tower (paper Fig. 2: the NN side is
computation-intensive, 50+ TFLOP per step at production scale). On GPU the
paper delegates these GEMMs to cuBLAS; our TPU-idiom rethink expresses the
HBM<->VMEM schedule explicitly with a ``BlockSpec`` grid over (M, N, K) tiles
sized for the MXU systolic array (128-multiples where the preset dims allow)
and accumulates in the output block (f32), applying bias + activation once on
the final K step.

Lowered with ``interpret=True`` so the resulting HLO runs on any PJRT backend
(real-TPU Mosaic lowering cannot execute on the CPU plugin; see
DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes. For small presets the wrapper clamps these
# to the (padded) problem size so a tile never exceeds the array.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _apply_activation(y, activation: str):
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "sigmoid":
        return jax.nn.sigmoid(y)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation: {activation}")


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps: int, activation: str):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j]; finalize on last k."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finalize():
        o_ref[...] = _apply_activation(o_ref[...] + b_ref[...], activation)


def _pad_to(x, multiple: int, axis: int):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _fused_linear_pallas(x, w, b, activation, block_m, block_n, block_k):
    """Raw tiled Pallas ``act(x @ w + b)`` (no autodiff rule)."""
    m, k = x.shape
    n = w.shape[1]

    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, k))

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    bp = _pad_to(b.reshape(1, -1), bn, 1)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(
            _fused_linear_kernel, k_steps=k_steps, activation=activation
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def _act_grad_from_output(out, activation: str):
    """d act(y)/dy expressed from the *output* act(y) (what the fwd saved)."""
    if activation == "relu":
        return (out > 0).astype(out.dtype)
    if activation == "sigmoid":
        return out * (1.0 - out)
    if activation == "none":
        return jnp.ones_like(out)
    raise ValueError(f"unknown activation: {activation}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fused_linear_vjp(x, w, b, activation, block_m, block_n, block_k):
    return _fused_linear_pallas(x, w, b, activation, block_m, block_n, block_k)


def _fused_linear_fwd(x, w, b, activation, block_m, block_n, block_k):
    out = _fused_linear_pallas(x, w, b, activation, block_m, block_n, block_k)
    return out, (x, w, out)


def _fused_linear_bwd(activation, block_m, block_n, block_k, res, g):
    # The backward matmuls reuse the same tiled Pallas kernel (zero bias,
    # identity activation) so L1 is on the fwd AND bwd hot paths of the
    # exported train_step HLO.
    x, w, out = res
    dy = (g * _act_grad_from_output(out, activation)).astype(jnp.float32)
    zx = jnp.zeros((x.shape[1],), jnp.float32)
    zw = jnp.zeros((w.shape[1],), jnp.float32)
    dx = _fused_linear_pallas(dy, w.T.astype(jnp.float32), zx, "none", block_m, block_n, block_k)
    dw = _fused_linear_pallas(x.T.astype(jnp.float32), dy, zw, "none", block_m, block_n, block_k)
    db = jnp.sum(dy, axis=0)
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(jnp.float32)


_fused_linear_vjp.defvjp(_fused_linear_fwd, _fused_linear_bwd)


def fused_linear(
    x,
    w,
    b,
    activation: str = "relu",
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
):
    """Compute ``act(x @ w + b)`` with a tiled Pallas kernel (differentiable).

    x: [M, K], w: [K, N], b: [N]. Arbitrary shapes are padded up to the tile
    grid and the result sliced back, so callers never need tile-aligned dims.
    Accumulation is always f32 (``preferred_element_type``); inputs may be
    f32 or bf16. Gradients flow through a custom VJP whose matmuls are the
    same Pallas kernel.
    """
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} w{w.shape} b{b.shape}")
    if x.shape[1] != w.shape[0] or b.shape[0] != w.shape[1]:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    return _fused_linear_vjp(x, w, b, activation, block_m, block_n, block_k)


def vmem_footprint_bytes(
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    in_dtype_bytes: int = 4,
) -> int:
    """Estimated resident VMEM per grid step (x, w, b blocks + f32 out block).

    Used by the §Perf analysis in EXPERIMENTS.md — interpret-mode wallclock is
    not a TPU proxy, so we budget structurally: the working set must fit the
    ~16 MiB VMEM of a TPU core with room for double-buffering (×2).
    """
    x_blk = block_m * block_k * in_dtype_bytes
    w_blk = block_k * block_n * in_dtype_bytes
    b_blk = block_n * in_dtype_bytes
    o_blk = block_m * block_n * 4
    return 2 * (x_blk + w_blk + b_blk) + o_blk
