"""L1 Pallas kernel pair: non-uniform fp32 -> fp16 value compression.

Paper §4.2.3 ("Lossy compression"): a uniform fp32->fp16 cast harms statistic
efficiency, so each vector block v is first scaled by kappa/||v||_inf (kappa a
large constant near the fp16 max) and only then cast; the decompressor undoes
the scale. This keeps the mantissa bits where the signal is regardless of the
block's dynamic range.

The production hot path runs the same transform in Rust (`comm::compress`);
this kernel is the TPU-side counterpart (e.g. compressing embedding-gradient
traffic on-device before it leaves the NN worker) and doubles as the
executable specification the Rust implementation is property-tested against.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Close to (but safely under) the fp16 max of 65504.
KAPPA = 60000.0

BLOCK_ROWS = 256


def _compress_kernel(v_ref, out_ref, scale_ref):
    v = v_ref[...]
    norm = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    # Avoid 0/0 for all-zero rows; their values compress to exact zeros.
    safe = jnp.where(norm > 0, norm, 1.0)
    out_ref[...] = (v * (KAPPA / safe)).astype(jnp.float16)
    # Stored per-row factor for the decompressor: ||v||_inf / kappa.
    scale_ref[...] = norm / KAPPA


def _decompress_kernel(c_ref, scale_ref, out_ref):
    out_ref[...] = c_ref[...].astype(jnp.float32) * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def compress(v, block_rows: int = BLOCK_ROWS):
    """Compress ``v: [R, D]`` f32 -> (``[R, D]`` f16 values, ``[R, 1]`` f32 scales)."""
    if v.ndim != 2:
        raise ValueError(f"expected [R, D], got {v.shape}")
    r, d = v.shape
    br = min(block_rows, max(1, r))
    pr = (-r) % br
    vp = jnp.pad(v, ((0, pr), (0, 0)))
    rp = vp.shape[0]

    vals, scales = pl.pallas_call(
        _compress_kernel,
        grid=(rp // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, d), jnp.float16),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=True,
    )(vp)
    return vals[:r], scales[:r]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def decompress(vals, scales, block_rows: int = BLOCK_ROWS):
    """Inverse of :func:`compress`."""
    if vals.ndim != 2 or scales.ndim != 2:
        raise ValueError(f"bad ranks: vals{vals.shape} scales{scales.shape}")
    r, d = vals.shape
    br = min(block_rows, max(1, r))
    pr = (-r) % br
    vp = jnp.pad(vals, ((0, pr), (0, 0)))
    sp = jnp.pad(scales, ((0, pr), (0, 0)))
    rp = vp.shape[0]

    out = pl.pallas_call(
        _decompress_kernel,
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, d), jnp.float32),
        interpret=True,
    )(vp, sp)
    return out[:r]
