"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: each kernel in this package is
asserted allclose against its oracle by ``python/tests`` (hypothesis sweeps
over shapes and dtypes) before anything is AOT-exported for the Rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .compress import KAPPA


def fused_linear_ref(x, w, b, activation: str = "relu"):
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(
        jnp.float32
    )
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "sigmoid":
        return jax.nn.sigmoid(y)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation: {activation}")


def embedding_bag_ref(x, mode: str = "sum"):
    s = jnp.sum(x.astype(jnp.float32), axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        return s / x.shape[1]
    raise ValueError(f"unknown mode: {mode}")


def compress_ref(v):
    norm = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    safe = jnp.where(norm > 0, norm, 1.0)
    vals = (v * (KAPPA / safe)).astype(jnp.float16)
    scales = norm / KAPPA
    return vals, scales


def decompress_ref(vals, scales):
    return vals.astype(jnp.float32) * scales
