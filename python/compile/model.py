"""L2: the Persia dense recommender tower (JAX, build-time only).

The paper's model (§2.1, Fig. 2): ID-type features pass through the huge
embedding layer (owned by the Rust embedding PS at runtime), get pooled per
feature group on the embedding workers, and the concatenated pooled
embeddings + Non-ID dense features feed a fully-connected tower — the paper's
benchmarks use an FFNN with hidden dims 4096/2048/1024/512/256 predicting CTR
with a binary cross-entropy loss.

This module defines exactly the dense part: given the pooled embedding
activations (``emb``), the dense features (``nid``) and labels, it computes
the loss and the gradients w.r.t. the dense parameters *and w.r.t. the
embedding activations* — the latter are shipped back to the embedding workers
(Algorithm 1's backward task). The Rust NN workers drive the AOT-compiled
``train_step`` of this module via PJRT; Python never runs at training time.

Every hidden layer is the L1 Pallas ``fused_linear`` kernel so the kernels
lower into the same HLO module (interpret=True; see kernels/fused_mlp.py).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.fused_mlp import fused_linear

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]


def layer_dims(emb_dim: int, nid_dim: int, hidden: Sequence[int]) -> List[int]:
    """Full list of layer widths: input, hidden..., 1 logit."""
    return [emb_dim + nid_dim, *hidden, 1]


def init_params(key, dims: Sequence[int]) -> Params:
    """He-initialised weights, zero biases, one (W, b) per layer."""
    params = []
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        fan_in = dims[i]
        w = jax.random.normal(sub, (dims[i], dims[i + 1]), jnp.float32)
        w = w * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((dims[i + 1],), jnp.float32)
        params.append((w, b))
    return params


def param_count(dims: Sequence[int]) -> int:
    return sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))


def tower_logits(params: Params, emb, nid, use_pallas: bool = True):
    """Forward pass: concat(pooled embeddings, dense features) -> logit [B]."""
    x = jnp.concatenate([emb, nid], axis=1)
    for i, (w, b) in enumerate(params):
        last = i == len(params) - 1
        act = "none" if last else "relu"
        if use_pallas:
            x = fused_linear(x, w, b, activation=act)
        else:
            y = x @ w + b
            x = y if last else jnp.maximum(y, 0.0)
    return x[:, 0]


def bce_loss(logits, y):
    """Mean binary cross-entropy with logits (numerically stable form)."""
    return jnp.mean(jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def loss_fn(params: Params, emb, nid, y, use_pallas: bool = True):
    return bce_loss(tower_logits(params, emb, nid, use_pallas=use_pallas), y)


def train_step(params: Params, emb, nid, y, use_pallas: bool = True):
    """One SGD step's compute: (loss, dense grads, grad wrt emb activations).

    A single value_and_grad graph — no recomputation of the tower between the
    loss and the gradients (L2 §Perf requirement).
    """
    (loss, _), grads = jax.value_and_grad(
        lambda p, e: (loss_fn(p, e, nid, y, use_pallas=use_pallas), 0.0),
        argnums=(0, 1),
        has_aux=True,
    )(params, emb)
    gparams, gemb = grads
    return loss, gparams, gemb


def forward(params: Params, emb, nid, use_pallas: bool = True):
    """Eval graph: predicted CTR probabilities [B]."""
    return jax.nn.sigmoid(tower_logits(params, emb, nid, use_pallas=use_pallas))


# ---------------------------------------------------------------------------
# Flat-argument wrappers: the AOT interchange with Rust uses a fixed
# positional convention (w0, b0, ..., wk, bk, emb, nid, y) so the Rust side
# never needs a pytree library.
# ---------------------------------------------------------------------------


def _unflatten(args, n_layers: int) -> Tuple[Params, tuple]:
    params = [(args[2 * i], args[2 * i + 1]) for i in range(n_layers)]
    return params, args[2 * n_layers :]


def train_step_flat(n_layers: int, use_pallas: bool = True):
    """Returns f(w0, b0, ..., emb, nid, y) -> (loss, gw0, gb0, ..., gemb)."""

    def f(*args):
        params, (emb, nid, y) = _unflatten(args, n_layers)
        loss, gparams, gemb = train_step(params, emb, nid, y, use_pallas=use_pallas)
        flat = [loss]
        for gw, gb in gparams:
            flat.extend([gw, gb])
        flat.append(gemb)
        return tuple(flat)

    return f


def forward_flat(n_layers: int, use_pallas: bool = True):
    """Returns f(w0, b0, ..., emb, nid) -> (probs,)."""

    def f(*args):
        params, (emb, nid) = _unflatten(args, n_layers)
        return (forward(params, emb, nid, use_pallas=use_pallas),)

    return f
